"""AEAD fast-path benchmarks (ISSUE 2): batched ``seal_many`` vs the
per-block eager ``vmap(seal)`` it replaced, sealed-vs-plain exchange
throughput on the bench_dist mailbox shapes, and the shape-keyed compile
cache (round 2 must be all cache hits).

Rows feed the README "Performance" table and the BENCH_aead.json CI
artifact (``python -m benchmarks.run --only aead --json``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.attest.directory import ephemeral_edge_key
from repro.crypto import aead
from repro.dist import collectives
from repro.launch.mesh import make_smoke_mesh


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    # --- batched seal_many vs per-block eager vmap(seal) --------------------
    # the bench_dist mailbox shape: W² blocks of nb 16-word cipher blocks
    mesh = make_smoke_mesh()
    axis = "model"
    Wm = int(mesh.shape[axis])
    nb = 64 if quick else 256
    B, n_words = Wm * Wm, nb * 16
    kw = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2 ** 32, (B, 3), dtype=np.uint32))
    words = jnp.asarray(rng.integers(0, 2 ** 32, (B, n_words),
                                     dtype=np.uint32))
    mbytes = B * n_words * 4 / 1e6

    us_eager = time_fn(
        lambda: jax.vmap(aead.seal, in_axes=(None, 0, 0))(kw, nonces, words),
        warmup=1, iters=3)
    rows.append((f"aead.seal.vmap_eager.B{B}.n{n_words}", us_eager,
                 f"MB_per_s={mbytes / (us_eager / 1e6):.1f}"))

    for backend in ("pallas", "jnp"):
        us = time_fn(lambda: aead.seal_many(kw, nonces, words,
                                            backend=backend),
                     warmup=2, iters=5)
        rows.append((f"aead.seal_many.{backend}.B{B}.n{n_words}", us,
                     f"MB_per_s={mbytes / (us / 1e6):.1f}"
                     f";speedup_vs_eager={us_eager / us:.1f}x"))

    ct, tags = aead.seal_many(kw, nonces, words)
    us = time_fn(lambda: aead.open_many(kw, nonces, ct, tags),
                 warmup=2, iters=5)
    rows.append((f"aead.open_many.pallas.B{B}.n{n_words}", us,
                 f"MB_per_s={mbytes / (us / 1e6):.1f}"))

    # --- compile cache: round 2 of a fresh shape must be all hits -----------
    aead.reset_fastpath_cache()
    fresh = jnp.asarray(rng.integers(0, 2 ** 32, (B, n_words + 16),
                                     dtype=np.uint32))
    aead.seal_many(kw, nonces, fresh)           # round 1: compiles
    s0 = aead.fastpath_stats()
    aead.seal_many(kw, nonces, fresh)           # round 2: hits
    s1 = aead.fastpath_stats()
    rows.append(("aead.compile_cache.round2", 0.0,
                 f"compiles={s1['compiles']};hits={s1['hits']};"
                 f"round2_compiled={int(s1['compiles'] != s0['compiles'])}"))

    # --- sealed vs plain exchange throughput (mailbox all_to_all) -----------
    nbx = 256 if quick else 1024
    x = jax.random.normal(jax.random.key(2), (Wm, Wm, nbx, 16), jnp.float32)
    skey = ephemeral_edge_key("bench-aead", seed=0)
    xbytes = x.size * 4 / 1e6

    us_plain = time_fn(lambda: collectives.exchange(x, mesh, axis),
                       warmup=1, iters=3)
    rows.append((f"aead.exchange.plain.W{Wm}", us_plain,
                 f"MB_per_s={xbytes / (us_plain / 1e6):.1f}"))

    warmup, iters = 1, 3
    c0 = collectives.exchange_call_count()
    us_sealed = time_fn(
        lambda: collectives.secure_exchange(x, mesh, axis, key=skey,
                                            step=0)[0],
        warmup=warmup, iters=iters)
    calls = collectives.exchange_call_count() - c0
    rows.append((f"aead.exchange.sealed.W{Wm}", us_sealed,
                 f"MB_per_s={xbytes / (us_sealed / 1e6):.1f}"
                 f";collectives_per_round={calls / (warmup + iters):.0f}"
                 f";sealed_over_plain={us_sealed / us_plain:.1f}x"))
    return rows
