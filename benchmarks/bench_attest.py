"""Attestation & key-lifecycle benchmarks (ISSUE 3).

Control-plane costs of the `repro.attest` subsystem: the quote-checked DH
handshake (per edge), quote generate+verify alone, the per-epoch rotation
ratchet across a realistic edge count, and the data-plane question that
decides whether mid-stream rekeying is affordable — sealed-exchange
latency when every round flips the epoch vs a steady key (the AEAD
compile cache is keyed on shapes, not keys, so a flip must not recompile).

Rows feed ``BENCH_attest.json`` (``python -m benchmarks.run --only attest
--json``), uploaded as a CI artifact next to the AEAD bench.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.attest.directory import KeyDirectory
from repro.attest.measure import measure_bytes
from repro.dist import collectives
from repro.launch.mesh import make_smoke_mesh


def _directory_with_edges(n_edges: int, seed: int = 0) -> KeyDirectory:
    d = KeyDirectory(seed=seed)
    for s in range(n_edges + 1):
        d.enroll(f"stage{s}", measure_bytes(b"bench-stage", str(s).encode()),
                 allow=True)
    for s in range(n_edges):
        d.establish(f"edge{s}", f"stage{s}", f"stage{s + 1}", stage_id=s)
    return d


def run(quick: bool = False):
    rows = []

    # --- handshake latency: quote x2 + verify x2 + DH + transcript KDF ----
    d = _directory_with_edges(0)
    d.enroll("hs/a", measure_bytes(b"hs"), allow=True)
    d.enroll("hs/b", measure_bytes(b"hs"), allow=True)
    n = [0]

    def handshake():
        n[0] += 1
        return d.establish(f"hs-edge{n[0]}", "hs/a", "hs/b")

    us = time_fn(handshake, warmup=1, iters=3 if quick else 7)
    rows.append(("attest.handshake.establish", us,
                 f"edges_per_s={1e6 / us:.0f}"))

    # --- quote generate + verify alone (the admission gate) --------------
    us = time_fn(lambda: d.admit("hs/a"), warmup=2, iters=10)
    rows.append(("attest.quote.admit", us, f"admits_per_s={1e6 / us:.0f}"))

    # --- rotation: ratchet every edge key + reset counters ----------------
    E = 8
    dr = _directory_with_edges(E)
    us = time_fn(dr.advance_epoch, warmup=1, iters=5 if quick else 20)
    rows.append((f"attest.rotation.advance_epoch.E{E}", us,
                 f"us_per_edge={us / E:.1f}"))

    # --- sealed exchange across an epoch flip vs steady key ---------------
    # same shapes every round -> the AEAD compile cache must hit whether or
    # not the key rotated; the delta IS the rotation overhead on the wire.
    mesh = make_smoke_mesh()
    axis = "model"
    Wm = int(mesh.shape[axis])
    nb = 64 if quick else 256
    x = jax.random.normal(jax.random.key(0), (Wm, Wm, nb, 16), jnp.float32)
    dx = _directory_with_edges(1, seed=1)
    h = dx.handle("edge0")

    us_steady = time_fn(
        lambda: collectives.secure_exchange(x, mesh, axis, key=h)[0],
        warmup=2, iters=5)
    rows.append((f"attest.exchange.steady_epoch.W{Wm}", us_steady,
                 f"MB_per_s={x.size * 4 / us_steady:.1f}"))

    def flip_round():
        dx.advance_epoch()
        return collectives.secure_exchange(x, mesh, axis, key=h)[0]

    us_flip = time_fn(flip_round, warmup=2, iters=5)
    rows.append((f"attest.exchange.epoch_flip.W{Wm}", us_flip,
                 f"MB_per_s={x.size * 4 / us_flip:.1f}"
                 f";flip_over_steady={us_flip / us_steady:.2f}x"))
    return rows
