"""Paper Fig. 4: time to move a fixed payload through the enclave as a
function of chunk size, one-way (in) and round-trip (in/out).

Paper finding: overhead amortizes at chunks >= 64 KB; in/out costs at most
+20% over in.  TPU analogue: the payload crosses the enclave kernel in
chunks of ``chunk_bytes``; small chunks pay per-launch (call-gate) costs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.kernels.enclave_map import ops as eops

PAYLOAD_MB = 4  # scaled from the paper's 100 MB for 1-CPU-core CI


def run(quick: bool = False):
    rows: list = []
    rng = np.random.default_rng(0)
    k1 = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    k2 = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    nonce = jnp.asarray(rng.integers(0, 2 ** 32, 3, dtype=np.uint32))
    payload_mb = 4 if quick else PAYLOAD_MB
    total_blocks = payload_mb * (1 << 20) // 64
    data = jnp.asarray(rng.integers(0, 2 ** 32, (total_blocks, 16),
                                    dtype=np.uint32))

    sizes_kb = [16, 64, 256] if quick else [16, 64, 256, 1024]
    for kb in sizes_kb:
        rows_per_chunk = max(kb * 1024 // 64, 1)
        n_chunks = max(total_blocks // rows_per_chunk, 1)

        def push(round_trip: bool):
            outs = []
            for c in range(n_chunks):
                blk = jax.lax.dynamic_slice(
                    data, (c * rows_per_chunk, 0), (rows_per_chunk, 16))
                out = eops.enclave_map(k1, k2, nonce, 1 + c * rows_per_chunk,
                                       blk, op="identity",
                                       block_rows=min(rows_per_chunk, 512))
                if round_trip:
                    out = eops.enclave_map(k2, k1, nonce,
                                           1 + c * rows_per_chunk, out,
                                           op="identity",
                                           block_rows=min(rows_per_chunk, 512))
                outs.append(out)
            return outs[-1]

        t_in = time_fn(lambda: push(False), warmup=1, iters=3)
        t_inout = time_fn(lambda: push(True), warmup=1, iters=3)
        mbps_in = payload_mb / (t_in / 1e6)
        rows.append((f"chunk_copy.in.{kb}KB", t_in,
                     f"{mbps_in:.1f}MB/s"))
        rows.append((f"chunk_copy.inout.{kb}KB", t_inout,
                     f"overhead={(t_inout / t_in - 1) * 100:.0f}%"))
    return rows
