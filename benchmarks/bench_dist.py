"""repro.dist microbenchmarks: pipeline-parallel schedule throughput
(sealed vs. plain stage boundaries) and the secure sharded shuffle.

These start the BENCH trajectory for the distribution subsystem: the cost
of AEAD-sealing every GPipe stage boundary (the paper's inter-worker
encryption, Fig. 6) and of the encrypted all_to_all behind the router's
shuffle/keyed policies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.attest.directory import ephemeral_edge_key
from repro.dist.collectives import exchange, keyed_route, secure_exchange
from repro.dist.pipeline_parallel import edge_directory, pipeline_apply
from repro.launch.mesh import make_smoke_mesh


def run(quick: bool = False):
    rows = []
    S = 2 if quick else 4                     # pipeline stages
    M = 4 if quick else 8                     # microbatches
    d = 64 if quick else 128
    mb = 8

    W = jax.random.normal(jax.random.key(0), (S, d, d), jnp.float32)
    xs = jax.random.normal(jax.random.key(1), (M, mb, d), jnp.float32)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    # attested sessions established once (control plane); the timed loop
    # measures the sealed data plane only.  A distinct step per invocation
    # keeps every per-edge (key, nonce) pair unique across iterations.
    import itertools
    pp_dir = edge_directory(S, seed=0)
    pp_step = itertools.count()
    for seal in (False, True):
        us = time_fn(lambda: pipeline_apply(stage_fn, W, xs, None, seal=seal,
                                            directory=pp_dir,
                                            step=next(pp_step)),
                     warmup=1, iters=3)
        toks = M * mb
        rows.append((f"dist.pp_apply.S{S}.M{M}.seal{int(seal)}", us,
                     f"rows_per_s={toks / (us / 1e6):.0f}"))

    # sharded shuffle: mailbox all_to_all over the smoke mesh's model axis
    mesh = make_smoke_mesh()
    axis = "model"
    Wm = int(mesh.shape[axis])
    nb = 256 if quick else 1024
    x = jax.random.normal(jax.random.key(2), (Wm, Wm, nb, 16), jnp.float32)
    key = ephemeral_edge_key("shuffle", seed=0)

    us = time_fn(lambda: exchange(x, mesh, axis), warmup=1, iters=3)
    mbytes = x.size * 4 / 1e6
    rows.append((f"dist.shuffle.plain.W{Wm}", us,
                 f"MB_per_s={mbytes / (us / 1e6):.0f}"))
    us = time_fn(lambda: secure_exchange(x, mesh, axis, key=key, step=0)[0],
                 warmup=1, iters=3)
    rows.append((f"dist.shuffle.sealed.W{Wm}", us,
                 f"MB_per_s={mbytes / (us / 1e6):.0f}"))

    # keyed routing (consistent hash -> bucket -> exchange)
    n = 512 if quick else 2048
    rowsx = jax.random.normal(jax.random.key(3), (Wm, n, 8), jnp.float32)
    rkeys = jax.random.randint(jax.random.key(4), (Wm, n), 0, 1 << 20)
    us = time_fn(lambda: keyed_route(rowsx, rkeys, mesh, axis, key=key,
                                     step=0)[0],
                 warmup=1, iters=3)
    rows.append((f"dist.keyed_route.sealed.W{Wm}", us,
                 f"rows_per_s={Wm * n / (us / 1e6):.0f}"))
    return rows
