"""µbench 1 (paper §5.3 first experiment): cost of a call into the enclave.

Paper: native function call 23.6 ns vs ~2.35 µs ecall (~100x).  TPU
analogue: a plain jit dispatch (native) vs the enclave kernel dispatch
(decrypt+op+encrypt fused pallas call) on a minimal chunk — the "call gate"
is the kernel launch + keystream derivation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.crypto import chacha20
from repro.kernels.enclave_map import ops as eops


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    key1 = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    key2 = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    nonce = jnp.asarray(rng.integers(0, 2 ** 32, 3, dtype=np.uint32))
    blocks = jnp.asarray(rng.integers(0, 2 ** 32, (256, 16), dtype=np.uint32))

    plain = jax.jit(lambda x: x ^ np.uint32(1))
    t_native = time_fn(lambda: plain(blocks), iters=20)
    rows.append(("ecall.native_jit_call", t_native, "baseline"))

    t_enclave = time_fn(lambda: eops.enclave_map(
        key1, key2, nonce, 1, blocks, op="identity", block_rows=256),
        iters=10)
    rows.append(("ecall.enclave_kernel_call", t_enclave,
                 f"ratio={t_enclave / max(t_native, 1e-9):.1f}x"))

    t_cipher = time_fn(lambda: chacha20.encrypt_words(
        key1, nonce, blocks.reshape(-1)), iters=10)
    rows.append(("ecall.cipher_only_call", t_cipher,
                 f"ratio={t_cipher / max(t_native, 1e-9):.1f}x"))
    return rows
