"""Paper Fig. 5 / Table 2: compute benchmarks inside vs outside the enclave.

Paper finding: SGX/native ratio ~1.0 while the working set fits the EPC,
4.76x when it spills (binarytrees @ 664 MB).  TPU analogue: six compute
workloads run (a) natively on cleartext and (b) through the enclave data
path (sealed in, compute, sealed out).  The "EPC spill" analogue is the
chunked-vs-resident working set: when the payload exceeds the enclave tile
budget it is streamed through multiple kernel launches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.attest.directory import ephemeral_edge_key
from repro.core.enclave import EnclaveExecutor, ingress, egress

# six workloads (paper: dhrystone, fannkuchredux, nbody, richards,
# spectralnorm, binarytrees) -> TPU-friendly numeric equivalents with small
# and large working sets.
WORKLOADS = {
    "dhrystone": ("int_mix", 1 << 14),        # small int op mix
    "fannkuchredux": ("permsum", 1 << 14),
    "nbody": ("nbody", 1 << 12),
    "richards": ("int_mix", 1 << 16),
    "spectralnorm": ("matvec", 1 << 14),
    "binarytrees": ("treesum", 1 << 20),      # the big-working-set case
}


def _compute(kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "int_mix":
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
        for _ in range(8):
            w = (w * np.uint32(2654435761)) ^ (w >> np.uint32(13))
        return jax.lax.bitcast_convert_type(w, jnp.float32)
    if kind == "permsum":
        y = x
        for _ in range(8):
            y = jnp.roll(y, 7) + y[::-1] * 0.5
        return y
    if kind == "nbody":
        n = min(x.shape[0], 256)
        p = x[:n].reshape(-1, 1)
        d = p - p.T
        f = d / (jnp.abs(d) ** 3 + 1e-3)
        return jnp.tile(f.sum(1), (x.shape[0] // n + 1,))[:x.shape[0]]
    if kind == "matvec":
        n = 128
        m = x[:n * n].reshape(n, n) if x.shape[0] >= n * n else \
            jnp.ones((n, n), x.dtype)
        v = x[:n]
        for _ in range(4):
            v = m @ v
            v = v / (jnp.linalg.norm(v) + 1e-6)
        return jnp.tile(v, (x.shape[0] // n + 1,))[:x.shape[0]]
    if kind == "treesum":
        y = x
        while y.shape[0] > 1:
            half = y.shape[0] // 2
            y = y[:half] + y[half:2 * half]
        return jnp.tile(y, (x.shape[0],))
    raise ValueError(kind)


def run(quick: bool = False):
    rows = []
    k0 = ephemeral_edge_key("in", seed=0, stage_id=0)
    k1 = ephemeral_edge_key("out", seed=0, stage_id=1)
    items = list(WORKLOADS.items())
    if quick:
        items = items[:3]
    for name, (kind, n) in items:
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal(n).astype(np.float32))
        native = jax.jit(lambda v: _compute(kind, v))
        t_native = time_fn(lambda: native(x), iters=5)

        ex = EnclaveExecutor("encrypted", k0, k1)

        def secured():
            chunk = ingress("encrypted", k0, 0, x)
            out = ex.run(lambda v: _compute(kind, v), chunk)
            y, ok = egress("encrypted", k1, out)
            return y

        t_enc = time_fn(secured, warmup=1, iters=3)
        ratio = t_enc / max(t_native, 1e-9)
        mem_kb = n * 4 / 1024
        rows.append((f"enclave_compute.{name}", t_enc,
                     f"ratio={ratio:.2f}x mem={mem_kb:.0f}KB"))
    return rows
