"""Kernel throughput sweeps (beyond-paper): cipher, MAC, flash attention.
Interpret-mode numbers are CPU correctness-path timings; the derived column
reports bytes/FLOPs processed so TPU projections can be made from them."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.kernels.chacha20 import ops as cops
from repro.kernels.cwmac import ops as mops
from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.models.flash import flash_attention as flash_jnp


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    key = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
    nonce = jnp.asarray(rng.integers(0, 2 ** 32, 3, dtype=np.uint32))

    for mb in [1]:
        words = jnp.asarray(rng.integers(0, 2 ** 32, mb * (1 << 18),
                                         dtype=np.uint32))
        t = time_fn(lambda: cops.encrypt_words(key, nonce, words),
                    warmup=1, iters=3)
        rows.append((f"kern.chacha20.{mb}MB", t,
                     f"{mb / (t / 1e6):.1f}MB/s"))
        r = jnp.uint32(12345)
        s = jnp.uint32(6789)
        t = time_fn(lambda: mops.mac(words, r, s, tile=4096),
                    warmup=1, iters=3)
        rows.append((f"kern.cwmac.{mb}MB", t, f"{mb / (t / 1e6):.1f}MB/s"))

    B, H, D = 1, 2, 32
    for S in [256]:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
        flops = 4 * B * H * S * S * D / 2  # causal
        t = time_fn(lambda: flash_attention_bhsd(q, k, v, causal=True,
                                                 q_chunk=128, kv_chunk=128),
                    warmup=1, iters=3)
        rows.append((f"kern.flash_pallas.S{S}", t,
                     f"{flops / (t / 1e6) / 1e9:.2f}GFLOP/s"))
        qb, kb, vb = (x.swapaxes(1, 2) for x in (q, k, v))
        t2 = time_fn(lambda: flash_jnp(qb, kb, vb, True, 128, 128),
                     warmup=1, iters=3)
        rows.append((f"kern.flash_jnp.S{S}", t2,
                     f"{flops / (t2 / 1e6) / 1e9:.2f}GFLOP/s"))
    return rows
