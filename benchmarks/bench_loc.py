"""Paper Table 1: lines-of-code split (app vs library vs runtime)."""
from __future__ import annotations

import os


def _count(path: str, endswith=".py") -> int:
    total = 0
    for root, _, files in os.walk(path):
        for f in files:
            if f.endswith(endswith):
                with open(os.path.join(root, f)) as fh:
                    total += sum(1 for line in fh
                                 if line.strip() and
                                 not line.strip().startswith("#"))
    return total


def run(quick: bool = False):
    base = os.path.join(os.path.dirname(__file__), "..")
    rows = []
    app = _count(os.path.join(base, "examples"))
    core = _count(os.path.join(base, "src", "repro", "core")) + \
        _count(os.path.join(base, "src", "repro", "crypto"))
    kernels = _count(os.path.join(base, "src", "repro", "kernels"))
    framework = _count(os.path.join(base, "src", "repro"))
    rows.append(("loc.examples(app)", 0.0, f"{app}LoC"))
    rows.append(("loc.securestreams(core+crypto)", 0.0, f"{core}LoC"))
    rows.append(("loc.kernels", 0.0, f"{kernels}LoC"))
    rows.append(("loc.framework_total", 0.0, f"{framework}LoC"))
    return rows
