"""Paper Fig. 6: full DelayedFlights pipeline throughput under the three
security configurations x {1, 2, 4} workers per stage, plus the
window-vectorized engine rows: ``pipeline.window.batched`` (windows of
B >= 8 chunks per batched open->op->seal dispatch, deferred MAC verdicts,
one host sync per window) vs ``pipeline.window.chunked`` (the
``window_chunks=1`` per-chunk oracle) on an 8-stage encrypted pipeline,
with a window-size sweep, a rekey+revocation bit-parity check, and a
``pipeline.dsl`` row — the same 8-stage job compiled by ``repro.dsl``,
proving the DSL adds zero overhead over the hand-built engine
(bit-identical output, throughput at parity).

Workers are modeled as chunk-batching across a stage's worker pool (W
chunks dispatched per call — on a real mesh those are W parallel shards;
on this 1-core CPU host the curve plateaus exactly as the paper's does
once worker count exceeds physical cores, §5.5).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.attest.directory import KeyDirectory
from repro.configs.base import SecureStreamConfig
from repro.core.pipeline import Pipeline, Stage
from repro.data.synthetic import CARRIER_WORD, DELAY_WORD, flight_chunks

N_RECORDS = 12_288
CHUNK = 1024


def _pipeline(mode: str, workers: int):
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid], minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(
            carrier[valid], weights=delay[valid], minlength=20)
        return acc

    return Pipeline([
        Stage("mapper", op="identity", workers=workers),
        Stage("filter", op="delay_filter_u32", const=15, workers=workers),
        Stage("reducer", op="custom", reduce_fn=reduce_fn,
              reduce_init={"count": np.zeros(20), "sum": np.zeros(20)},
              workers=1),
    ], SecureStreamConfig(mode=mode))


def _stage8(n_map: int = 8):
    """n_map encrypted map stages + terminal reduce (the Fig-6-style
    8-stage acceptance pipeline for the windowed-engine rows)."""
    def reduce_fn(acc, chunk):
        return chunk if acc is None else acc + np.asarray(chunk)

    stages = [Stage(f"s{i}", op="scale_f32", const=1.0 + 0.0625 * i,
                    workers=2 if i == 2 else 1)      # s2 survives revocation
              for i in range(n_map)]
    stages.append(Stage("sum", op="custom", reduce_fn=reduce_fn,
                        reduce_init=None))
    return stages


def _build_manual(wc: int, seed: int = 0) -> Pipeline:
    return Pipeline(_stage8(), SecureStreamConfig(mode="encrypted"),
                    directory=KeyDirectory(seed=seed, epoch_history=64),
                    window_chunks=wc)


def _build_dsl(wc: int, seed: int = 0) -> Pipeline:
    """The same 8-stage job, compiled from the fluent DSL chain."""
    from repro.dsl import stream
    sb = stream()
    for i in range(8):
        sb = sb.map("scale_f32", const=1.0 + 0.0625 * i, name=f"s{i}",
                    workers=2 if i == 2 else 1)
    sb = (sb.reduce("sum", name="sum").secure("encrypted").window(wc)
          .directory(KeyDirectory(seed=seed, epoch_history=64)))
    return sb.build()


def _run_windowed(wc: int, n_chunks: int, chunk_words: int, *,
                  rekey=None, revoke_at=None, seed: int = 0,
                  build=_build_manual, tracer=None, monitor=None,
                  retry=None, chaos=None):
    """One 8-stage encrypted run at window factor ``wc``; returns
    (seconds, terminal reduce array)."""
    p = build(wc, seed)
    rng = np.random.default_rng(7)
    src = [jnp.asarray(rng.standard_normal(chunk_words).astype(np.float32))
           for _ in range(n_chunks)]

    def source():
        for i, c in enumerate(src):
            if revoke_at is not None and i == revoke_at:
                p.directory.revoke(Pipeline.worker_id("s2", 1))
            yield c

    t0 = time.perf_counter()
    out = p.run(source(), rekey_every_n=rekey, tracer=tracer,
                monitor=monitor, retry=retry, chaos=chaos)
    jax.block_until_ready(out)
    return time.perf_counter() - t0, np.asarray(out)


def run(quick: bool = False):
    rows = []
    n_records = 16_384 if quick else N_RECORDS
    worker_counts = [1, 2] if quick else [1, 2, 4]
    for mode in ("plain", "encrypted", "enclave"):
        for w in worker_counts:
            p = _pipeline(mode, w)
            # workers -> chunk batching: W chunks per dispatch
            eff_chunk = CHUNK * w
            t0 = time.perf_counter()
            out = p.run(jnp.asarray(c) for c in
                        flight_chunks(n_records, eff_chunk, seed=1))
            dt = time.perf_counter() - t0
            mb = n_records * 64 / 1e6
            rows.append((f"pipeline.{mode}.w{w}", dt * 1e6,
                         f"{mb / dt:.2f}MB/s delayed="
                         f"{int(out['count'].sum())}"))

    # ---- window-vectorized engine: batched vs per-chunk + size sweep ----
    # The wc=1 oracle is the seed per-chunk engine (eager scalar crypto +
    # one blocking verdict sync per chunk) — minutes per MB — so it runs
    # on a short slice and the comparison is MB/s, not wall seconds.
    chunk_words = 4096                      # 16 KiB/chunk
    n_chunks = 16 if quick else 32          # >= 2 windows of B=8 at wc=8
    n_oracle = 4 if quick else 8
    mb = n_chunks * chunk_words * 4 / 1e6
    mb_oracle = n_oracle * chunk_words * 4 / 1e6
    dt_chunked, out_chunked = _run_windowed(1, n_oracle, chunk_words)
    mbps_chunked = mb_oracle / dt_chunked
    rows.append(("pipeline.window.chunked", dt_chunked * 1e6,
                 f"{mbps_chunked:.2f}MB/s wc=1 per-chunk oracle "
                 f"({n_oracle} chunks)"))
    # bit-parity vs the oracle on the oracle's own source
    _, out_b = _run_windowed(8, n_oracle, chunk_words)
    assert np.array_equal(out_b, out_chunked), \
        "windowed engine diverged from the per-chunk oracle"
    sweep = [8] if quick else [2, 4, 8, 16]
    best = 0.0
    mbps_hand = 0.0
    for wc in sweep:
        _run_windowed(wc, n_chunks, chunk_words)          # compile warmup
        dt, _ = _run_windowed(wc, n_chunks, chunk_words)
        name = "pipeline.window.batched" if wc == 8 \
            else f"pipeline.window.batched.w{wc}"
        speed = (mb / dt) / mbps_chunked
        rows.append((name, dt * 1e6,
                     f"{mb / dt:.2f}MB/s {speed:.1f}x vs per-chunk "
                     f"(wc={wc})"))
        best = max(best, speed)
        if wc == 8:
            mbps_hand = max(mbps_hand, mb / dt)

    # ---- DSL-compiled engine: zero overhead vs hand-built -------------
    # Same 8-stage job declared via repro.dsl: bit-identical terminal
    # reduce, throughput at parity (the DSL emits a plain Pipeline and
    # contributes nothing to the streaming hot path).  Best-of-2 on both
    # sides to keep the ratio honest under CPU noise.
    _, out_dsl = _run_windowed(8, n_oracle, chunk_words, build=_build_dsl)
    assert np.array_equal(out_dsl, out_chunked), \
        "DSL-compiled pipeline diverged from the hand-built oracle"
    _run_windowed(8, n_chunks, chunk_words, build=_build_dsl)   # warmup
    mbps_dsl = 0.0
    for _ in range(2):
        dt_hand, _ = _run_windowed(8, n_chunks, chunk_words)
        mbps_hand = max(mbps_hand, mb / dt_hand)
        dt_dsl, _ = _run_windowed(8, n_chunks, chunk_words,
                                  build=_build_dsl)
        mbps_dsl = max(mbps_dsl, mb / dt_dsl)
    ratio = mbps_dsl / mbps_hand
    rows.append(("pipeline.dsl", (mb / mbps_dsl) * 1e6,
                 f"{mbps_dsl:.2f}MB/s {ratio:.2f}x vs hand-built "
                 f"(bit-identical, wc=8)"))
    # ---- span tracing budget: <= 2% enabled, parity disabled ----------
    # Same 8-stage windowed job with a live Tracer attached vs the
    # zero-cost NULL_TRACER default.  Tracing records a handful of spans
    # per *window* (not per chunk), so the enabled overhead is noise-level
    # on this engine.  Untraced/traced runs are measured as INTERLEAVED
    # pairs (so clock drift and CPU throttling hit both sides equally,
    # not just whichever ran second) with best-of-N per side, and up to
    # two extra rounds re-measure before the budget assert ever fires.
    # The sample trace is exported for the CI artifact upload.
    from repro.obs.trace import Tracer
    reps = 2 if quick else 3
    tracer = None

    def _pair():
        nonlocal tracer
        off, _ = _run_windowed(8, n_chunks, chunk_words)
        t = Tracer()
        on, _ = _run_windowed(8, n_chunks, chunk_words, tracer=t)
        tracer = t
        return off, on

    dt_off = dt_on = float("inf")
    for round_ in range(3):                    # extra rounds only if over
        for _ in range(reps):
            off, on = _pair()
            dt_off = min(dt_off, off)
            dt_on = min(dt_on, on)
        if dt_on / dt_off - 1.0 <= 0.02:
            break
    overhead = dt_on / dt_off - 1.0
    tracer.export_chrome("trace.json")
    assert overhead <= 0.02, \
        f"tracing overhead {overhead * 100:.1f}% exceeds the 2% budget"
    rows.append(("pipeline.traced", dt_on * 1e6,
                 f"overhead={max(0.0, overhead) * 100:.1f}% (budget <=2% "
                 f"enabled, 0% disabled) spans={len(tracer)} "
                 f"trace.json exported"))

    # ---- live health monitor budget: <= 3% enabled, parity disabled ---
    # Same interleaved-pair discipline as pipeline.traced: a monitored
    # run folds one record_window per stage round into the sliding
    # stats; the unmonitored engine holds NULL_MONITOR (one attribute
    # check per window).  The detail string carries the per-run device
    # dispatch accounting — total compiled-program launches (counted in
    # the eager wrappers, never in traced code) and launches-per-window
    # at a representative single-worker encrypted hop, which must stay
    # at 2 (open_many + seal_many).
    from repro.obs.metrics import dispatch_count, reset_dispatch_count
    from repro.obs.monitor import PipelineMonitor
    monitor = None
    disp_run = 0

    def _mpair():
        nonlocal monitor, disp_run
        off, _ = _run_windowed(8, n_chunks, chunk_words)
        m = PipelineMonitor()
        reset_dispatch_count()
        on, _ = _run_windowed(8, n_chunks, chunk_words, monitor=m)
        disp_run = dispatch_count()
        monitor = m
        return off, on

    dt_moff = dt_mon = float("inf")
    for round_ in range(3):                    # extra rounds only if over
        for _ in range(reps):
            off, on = _mpair()
            dt_moff = min(dt_moff, off)
            dt_mon = min(dt_mon, on)
        if dt_mon / dt_moff - 1.0 <= 0.03:
            break
    m_overhead = dt_mon / dt_moff - 1.0
    assert m_overhead <= 0.03, \
        f"monitor overhead {m_overhead * 100:.1f}% exceeds the 3% budget"
    snap = monitor.snapshot()
    dpw = snap["stages"]["s1"]["dispatches_per_window"]
    rows.append(("pipeline.monitored", dt_mon * 1e6,
                 f"overhead={max(0.0, m_overhead) * 100:.1f}% (budget <=3% "
                 f"enabled, 0% disabled) dispatches={disp_run} "
                 f"dpw_s1={dpw:.1f} stages={len(snap['stages'])}"))

    # ---- fault-tolerant engine budget: <= 2% chaos-off, recovery
    # throughput chaos-on.  Chaos-off: the FT stage loop (replay buffer
    # retain/ack, per-share dispatch accounting, fault polls against an
    # empty plan) vs the plain window engine, as interleaved pairs with
    # the same escalating-rounds discipline as pipeline.traced.
    # Chaos-on: a fixed fault schedule (transient crash, tamper, dropped
    # verdict — retry + two replays, no wall-clock sleeps) reports
    # recovered MB/s and asserts the terminal reduce is bit-identical to
    # the fault-free run.
    from repro.ft.chaos import ChaosPlan, FaultSpec
    from repro.ft.retry import RetryPolicy

    def _cpair():
        off, o_off = _run_windowed(8, n_chunks, chunk_words)
        on, o_on = _run_windowed(8, n_chunks, chunk_words,
                                 retry=RetryPolicy())
        assert np.array_equal(o_off, o_on)
        return off, on

    _cpair()                               # untimed: compile the FT path
    dt_coff = dt_con = float("inf")
    for round_ in range(3):                    # extra rounds only if over
        for _ in range(reps):
            off, on = _cpair()
            dt_coff = min(dt_coff, off)
            dt_con = min(dt_con, on)
        if dt_con / dt_coff - 1.0 <= 0.02:
            break
    ft_overhead = dt_con / dt_coff - 1.0
    assert ft_overhead <= 0.02, \
        f"FT engine overhead {ft_overhead * 100:.1f}% (chaos off) " \
        f"exceeds the 2% budget"

    def _chaos_plan():
        return ChaosPlan(faults=[
            FaultSpec("crash", stage="s1", round=0, worker=0,
                      when="after"),
            FaultSpec("tamper", stage="s4", round=0, worker=0, rows=2),
            FaultSpec("drop_verdict", stage="s6", round=1, worker=0),
        ])

    _, out_ff = _run_windowed(8, n_chunks, chunk_words)
    plan = _chaos_plan()
    dt_chaos, out_chaos = _run_windowed(8, n_chunks, chunk_words,
                                        retry=RetryPolicy(), chaos=plan)
    assert not plan.pending(), plan.pending()
    assert np.array_equal(out_chaos, out_ff), \
        "chaos recovery diverged from the fault-free reduce"
    mb = n_chunks * chunk_words * 4 / 1e6
    rows.append(("pipeline.chaos", dt_con * 1e6,
                 f"overhead={max(0.0, ft_overhead) * 100:.1f}% (budget "
                 f"<=2% chaos off) recovery={mb / dt_chaos:.1f}MB/s "
                 f"({len(plan.events)} faults: retry+2 replays, "
                 f"bit-identical)"))

    # bit-identical terminal reduce under mid-stream rekeying + a live
    # revocation, batched engine vs the per-chunk oracle on the SAME
    # source (B>=8 windows straddle the epoch flips; a worker of s2 is
    # evicted mid-stream on both engines), and monitored vs unmonitored
    # on the batched engine (monitoring must not change a bit)
    _, out_rot_c = _run_windowed(1, n_oracle, chunk_words, rekey=3,
                                 revoke_at=n_oracle // 2)
    _, out_rot_b = _run_windowed(8, n_oracle, chunk_words, rekey=3,
                                 revoke_at=n_oracle // 2)
    _, out_rot_m = _run_windowed(8, n_oracle, chunk_words, rekey=3,
                                 revoke_at=n_oracle // 2,
                                 monitor=PipelineMonitor())
    parity = bool(np.array_equal(out_rot_b, out_rot_c)) and \
        bool(np.array_equal(out_rot_b, out_chunked)) and \
        bool(np.array_equal(out_rot_m, out_rot_b))
    rows.append(("pipeline.window.parity", 0.0,
                 f"bit_identical={parity} rekey_every_n=3+revocation"
                 f"+monitor speedup={best:.1f}x"))
    return rows
