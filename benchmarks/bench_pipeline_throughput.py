"""Paper Fig. 6: full DelayedFlights pipeline throughput under the three
security configurations x {1, 2, 4} workers per stage.

Workers are modeled as chunk-batching across a stage's worker pool (W
chunks dispatched per call — on a real mesh those are W parallel shards;
on this 1-core CPU host the curve plateaus exactly as the paper's does
once worker count exceeds physical cores, §5.5).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.configs.base import SecureStreamConfig
from repro.core.pipeline import Pipeline, Stage
from repro.data.synthetic import CARRIER_WORD, DELAY_WORD, flight_chunks

N_RECORDS = 12_288
CHUNK = 1024


def _pipeline(mode: str, workers: int):
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid], minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(
            carrier[valid], weights=delay[valid], minlength=20)
        return acc

    return Pipeline([
        Stage("mapper", op="identity", workers=workers),
        Stage("filter", op="delay_filter_u32", const=15, workers=workers),
        Stage("reducer", op="custom", reduce_fn=reduce_fn,
              reduce_init={"count": np.zeros(20), "sum": np.zeros(20)},
              workers=1),
    ], SecureStreamConfig(mode=mode))


def run(quick: bool = False):
    rows = []
    n_records = 16_384 if quick else N_RECORDS
    worker_counts = [1, 2] if quick else [1, 2, 4]
    for mode in ("plain", "encrypted", "enclave"):
        for w in worker_counts:
            p = _pipeline(mode, w)
            # workers -> chunk batching: W chunks per dispatch
            eff_chunk = CHUNK * w
            t0 = time.perf_counter()
            out = p.run(jnp.asarray(c) for c in
                        flight_chunks(n_records, eff_chunk, seed=1))
            dt = time.perf_counter() - t0
            mb = n_records * 64 / 1e6
            rows.append((f"pipeline.{mode}.w{w}", dt * 1e6,
                         f"{mb / dt:.2f}MB/s delayed="
                         f"{int(out['count'].sum())}"))
    return rows
