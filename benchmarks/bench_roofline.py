"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by launch/dryrun.py) and prints
per-cell terms; the derived column carries the dominant term + roofline
fraction.  Run the dry-run first: PYTHONPATH=src python -m repro.launch.dryrun
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(quick: bool = False):
    rows = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        return [("roofline.missing", 0.0,
                 "run: PYTHONPATH=src python -m repro.launch.dryrun")]
    for f in files:
        rec = json.load(open(f))
        cell = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skipped":
            rows.append((f"roofline.{cell}", 0.0, "SKIP(spec)"))
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline.{cell}", 0.0, "ERROR"))
            continue
        r = rec["roofline"]
        dom = r["dominant"][2:].replace("_s", "")
        step_s = max(r["t_compute_s"], r["t_mem_s"], r["t_coll_s"])
        rows.append((
            f"roofline.{cell}", step_s * 1e6,
            f"dom={dom} frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"mem={rec['memory']['peak_estimate_bytes'] / 1e9:.1f}GB"))
    return rows
