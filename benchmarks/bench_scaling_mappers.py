"""Paper Fig. 8: completion time scaling only the (most loaded) mapper
stage from 1..16 workers; filter and reducer stay at 1 worker each."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.configs.base import SecureStreamConfig
from repro.core.pipeline import Pipeline, Stage
from repro.data.synthetic import CARRIER_WORD, DELAY_WORD, flight_chunks

CHUNK = 512


def run(quick: bool = False):
    rows = []
    n_records = 8_192 if quick else 8_192
    reps = 2 if quick else 2
    mapper_counts = [1, 2, 4] if quick else [1, 2, 4, 8, 16]
    for w in mapper_counts:
        times = []
        per_worker = None
        for rep in range(reps):
            def reduce_fn(acc, chunk):
                delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
                acc["n"] += int((delay > 0).sum())
                return acc

            p = Pipeline([
                Stage("mapper", op="identity", workers=w),
                Stage("filter", op="delay_filter_u32", const=15, workers=1),
                Stage("reducer", op="custom", reduce_fn=reduce_fn,
                      reduce_init={"n": 0}, workers=1),
            ], SecureStreamConfig(mode="enclave"))
            t0 = time.perf_counter()
            p.run(jnp.asarray(c) for c in
                  flight_chunks(n_records, CHUNK * w, seed=rep))
            times.append(time.perf_counter() - t0)
            per_worker = p.report()["mapper"]["per_worker"]
        pw = "/".join(str(c) for c in per_worker)
        rows.append((f"scaling_mappers.m{w}", float(np.mean(times)) * 1e6,
                     f"std={float(np.std(times)) * 1e6:.0f}us "
                     f"mapper_chunks_per_worker={pw}"))
    return rows
