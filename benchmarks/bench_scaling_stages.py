"""Paper Fig. 7: completion time vs workers-per-stage (all stages enclave).
Repeated 5 times; reports mean and standard deviation."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.bench_pipeline_throughput import _pipeline, CHUNK


def run(quick: bool = False):
    rows = []
    n_records = 8_192 if quick else 8_192
    reps = 2 if quick else 2
    for w in ([1, 2] if quick else [1, 2, 4]):
        times = []
        for rep in range(reps):
            p = _pipeline("enclave", w)
            t0 = time.perf_counter()
            p.run(jnp.asarray(c) for c in __import__(
                "repro.data.synthetic", fromlist=["flight_chunks"]
            ).flight_chunks(n_records, CHUNK * w, seed=rep))
            times.append(time.perf_counter() - t0)
        mean, std = float(np.mean(times)), float(np.std(times))
        rows.append((f"scaling_stages.w{w}", mean * 1e6,
                     f"std={std * 1e6:.0f}us"))
    return rows
