"""Benchmark utilities: timing + CSV emission (`name,us_per_call,derived`)
plus the shared provenance block every JSON artifact embeds."""
from __future__ import annotations

import platform
import subprocess
import time
from datetime import datetime, timezone
from typing import Any, Callable, Dict, List, Tuple

import jax

Row = Tuple[str, float, str]


def bench_meta() -> Dict[str, Any]:
    """Provenance of a benchmark artifact: git SHA, UTC timestamp, jax /
    python versions, backend, platform.  Embedded in every
    ``BENCH_*.json`` so a number can always be tied back to the commit
    and environment that produced it."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "git_sha": sha,
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "jax": jax.__version__,
        "python": platform.python_version(),
        "backend": jax.default_backend(),
        "platform": platform.platform(),
    }


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or \
            isinstance(r, jax.Array) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        if isinstance(r, jax.Array):
            r.block_until_ready()
        else:
            jax.tree.map(lambda x: x.block_until_ready()
                         if isinstance(x, jax.Array) else x, r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
