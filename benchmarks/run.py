"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                               [--json [PATH]]
Output: CSV rows ``name,us_per_call,derived``; with ``--json`` also a
machine-readable ``BENCH_<name>.json`` artifact for the CI perf trajectory.
"""
from __future__ import annotations

import argparse
import ast
import importlib.util
import json
import platform
import sys
import traceback

from benchmarks.common import bench_meta, emit

MODULES = [
    ("ecall", "benchmarks.bench_ecall"),                 # §5.3 µbench 1
    ("chunk_copy", "benchmarks.bench_chunk_copy"),       # Fig. 4
    ("enclave_compute", "benchmarks.bench_enclave_compute"),  # Fig. 5 / T.2
    ("pipeline", "benchmarks.bench_pipeline_throughput"),     # Fig. 6
    ("scaling_stages", "benchmarks.bench_scaling_stages"),    # Fig. 7
    ("scaling_mappers", "benchmarks.bench_scaling_mappers"),  # Fig. 8
    ("dist", "benchmarks.bench_dist"),                   # repro.dist layer
    ("aead", "benchmarks.bench_aead"),                   # ISSUE 2 fast path
    ("attest", "benchmarks.bench_attest"),               # ISSUE 3 lifecycle
    ("loc", "benchmarks.bench_loc"),                     # Table 1
    ("kernels", "benchmarks.bench_kernels"),             # beyond-paper
    ("roofline", "benchmarks.bench_roofline"),           # §Roofline table
]


def _bench_descriptions() -> str:
    """One line per registered bench, sourced from each module's
    docstring (ast-parsed from source — no jax import just for --help)."""
    lines = ["registered benchmarks:"]
    for name, mod in MODULES:
        try:
            spec = importlib.util.find_spec(mod)
            with open(spec.origin, "r") as f:
                doc = ast.get_docstring(ast.parse(f.read())) or ""
            first = doc.strip().splitlines()[0] if doc.strip() else \
                "(no module docstring)"
        except Exception as e:                      # noqa: BLE001
            first = f"(unreadable: {e})"
        lines.append(f"  {name:16s} {first}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=_bench_descriptions())
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for --quick (CI smoke pass)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip", default=None,
                    help="comma-separated module names to skip")
    ap.add_argument("--json", nargs="?", const="auto", default=None,
                    metavar="PATH",
                    help="also write a JSON artifact (default path "
                         "BENCH_<only|all>.json)")
    args = ap.parse_args()
    args.quick = args.quick or args.smoke
    print("name,us_per_call,derived")
    failed = 0
    collected = []
    skips = set((args.skip or "").split(","))
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        if name in skips:
            continue
        try:
            m = __import__(mod, fromlist=["run"])
            rows = m.run(quick=args.quick)
            emit(rows)
            collected += [{"bench": name, "name": r[0], "us_per_call": r[1],
                           "derived": r[2]} for r in rows]
        except Exception:
            failed += 1
            print(f"{name},0.0,BENCH-ERROR", file=sys.stdout)
            traceback.print_exc()
    if args.json is not None:
        import jax
        path = args.json if args.json != "auto" else \
            f"BENCH_{args.only or 'all'}.json"
        with open(path, "w") as f:
            json.dump({"rows": collected, "failed": failed,
                       "quick": bool(args.quick),
                       "backend": jax.default_backend(),
                       "python": platform.python_version(),
                       "meta": bench_meta()}, f, indent=1)
        print(f"# wrote {path} ({len(collected)} rows)", file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
