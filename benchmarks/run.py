"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
Output: CSV rows ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    ("ecall", "benchmarks.bench_ecall"),                 # §5.3 µbench 1
    ("chunk_copy", "benchmarks.bench_chunk_copy"),       # Fig. 4
    ("enclave_compute", "benchmarks.bench_enclave_compute"),  # Fig. 5 / T.2
    ("pipeline", "benchmarks.bench_pipeline_throughput"),     # Fig. 6
    ("scaling_stages", "benchmarks.bench_scaling_stages"),    # Fig. 7
    ("scaling_mappers", "benchmarks.bench_scaling_mappers"),  # Fig. 8
    ("dist", "benchmarks.bench_dist"),                   # repro.dist layer
    ("loc", "benchmarks.bench_loc"),                     # Table 1
    ("kernels", "benchmarks.bench_kernels"),             # beyond-paper
    ("roofline", "benchmarks.bench_roofline"),           # §Roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for --quick (CI smoke pass)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    args.quick = args.quick or args.smoke
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        try:
            m = __import__(mod, fromlist=["run"])
            emit(m.run(quick=args.quick))
        except Exception:
            failed += 1
            print(f"{name},0.0,BENCH-ERROR", file=sys.stdout)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
