"""End-to-end driver: the paper's DelayedFlights macro-benchmark (§5.2).

Computes per-carrier average delay + delayed-flight counts over a synthetic
BTS-style stream under any of the three Fig.-6 security configurations,
with elastic per-stage worker scaling.

Run:  PYTHONPATH=src python examples/flight_delay_pipeline.py \
          --mode enclave --workers 2 --records 65536
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.base import SecureStreamConfig
from repro.core import Pipeline, Stage
from repro.data.synthetic import CARRIER_WORD, DELAY_WORD, flight_chunks

CARRIERS = 20


def build_pipeline(mode: str, workers: int) -> Pipeline:
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid],
                                                  minlength=CARRIERS)
        acc["sum"] = acc["sum"] + np.bincount(
            carrier[valid], weights=delay[valid], minlength=CARRIERS)
        return acc

    return Pipeline(
        [
            Stage("sgx_mapper", op="identity", workers=workers, sgx=True),
            Stage("sgx_filter", op="delay_filter_u32", const=15,
                  workers=workers, sgx=True),
            Stage("reducer", op="custom", reduce_fn=reduce_fn,
                  reduce_init={"count": np.zeros(CARRIERS),
                               "sum": np.zeros(CARRIERS)}),
        ],
        SecureStreamConfig(mode=mode),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="enclave",
                    choices=["plain", "encrypted", "enclave"])
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--records", type=int, default=65_536)
    ap.add_argument("--chunk", type=int, default=1024)
    args = ap.parse_args()

    pipe = build_pipeline(args.mode, args.workers)
    src = (jnp.asarray(c) for c in
           flight_chunks(args.records, args.chunk * args.workers, seed=1))
    t0 = time.perf_counter()
    out = pipe.run(src)
    dt = time.perf_counter() - t0
    mb = args.records * 64 / 1e6

    print(f"mode={args.mode} workers={args.workers} "
          f"records={args.records} ({mb:.1f} MB)")
    print(f"completed in {dt:.2f}s  ({mb / dt:.2f} MB/s)")
    print(f"{'carrier':>8} {'delayed':>9} {'avg delay':>10}")
    for c in range(CARRIERS):
        n = int(out["count"][c])
        avg = out["sum"][c] / max(n, 1)
        print(f"{c:>8} {n:>9} {avg:>9.1f}m")
    print("stage report:")
    for name, rep in pipe.report().items():
        print(f"  {name:12s} {rep}")


if __name__ == "__main__":
    main()
