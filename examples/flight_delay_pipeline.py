"""End-to-end driver: the paper's DelayedFlights macro-benchmark (§5.2).

Computes per-carrier average delay + delayed-flight counts over a synthetic
BTS-style stream under any of the three Fig.-6 security configurations,
with elastic per-stage worker scaling — declared in a few lines via the
fluent DSL (``repro.dsl``; pass ``--spec`` to load the equivalent TOML
spec instead).  See docs/dsl.md for the Listing-1/Listing-2 mapping.

Run:  PYTHONPATH=src python examples/flight_delay_pipeline.py \
          --mode enclave --workers 2 --records 65536
"""
import argparse
import os
import time

import jax.numpy as jnp

from repro.data.synthetic import flight_chunks
from repro.dsl import load_spec, stream

CARRIERS = 20

SPEC_PATH = os.path.join(os.path.dirname(__file__), "flight_delay.toml")


def build_pipeline(mode: str, workers: int):
    """The paper's Listing-1 job, fluent form.  The TOML spec next to
    this file is the declarative equivalent: both compile through the
    same validator/fusion path and produce bit-identical results
    (stage *structure* can differ only where fusion rules apply)."""
    return (stream()
            .map("identity", name="sgx_mapper", workers=workers, sgx=True)
            .filter("delay_filter_u32", const=15, name="sgx_filter",
                    workers=workers, sgx=True)
            .reduce("carrier_delay_stats", name="reducer")
            .secure(mode))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="enclave",
                    choices=["plain", "encrypted", "enclave"])
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--records", type=int, default=65_536)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--spec", action="store_true",
                    help=f"build from the TOML spec ({SPEC_PATH}) instead "
                         f"of the fluent chain")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record per-window spans and write a Chrome-trace "
                         "JSON here (open in chrome://tracing / Perfetto)")
    ap.add_argument("--serve-metrics", metavar="PORT", type=int,
                    default=None,
                    help="attach a live PipelineMonitor and serve "
                         "/metrics (Prometheus), /health and /snapshot "
                         "on this port while the job streams (0 = pick "
                         "an ephemeral port)")
    ap.add_argument("--serve-hold", metavar="SECONDS", type=float,
                    default=0.0,
                    help="with --serve-metrics: keep the endpoint up this "
                         "long after the run so scrapers can collect the "
                         "final snapshot (CI uses this)")
    args = ap.parse_args()

    if args.spec:
        pipe = (load_spec(SPEC_PATH).secure(args.mode)
                .scale("sgx_mapper", args.workers)
                .scale("sgx_filter", args.workers))
    else:
        pipe = build_pipeline(args.mode, args.workers)
    if args.trace:
        pipe = pipe.trace()
    srv = None
    if args.serve_metrics is not None:
        from repro.obs.export import serve_metrics
        pipe = pipe.monitor()
        srv = serve_metrics(args.serve_metrics,
                            monitor=pipe.health_monitor)
        print(f"live health: {srv.url}/metrics {srv.url}/health "
              f"{srv.url}/snapshot", flush=True)
    src = (jnp.asarray(c) for c in
           flight_chunks(args.records, args.chunk * args.workers, seed=1))
    t0 = time.perf_counter()
    out = pipe.run(src)
    dt = time.perf_counter() - t0
    mb = args.records * 64 / 1e6

    print(f"mode={args.mode} workers={args.workers} "
          f"records={args.records} ({mb:.1f} MB)")
    print(f"pipeline: {pipe.describe()}")
    print(f"completed in {dt:.2f}s  ({mb / dt:.2f} MB/s)")
    print(f"{'carrier':>8} {'delayed':>9} {'avg delay':>10}")
    for c in range(CARRIERS):
        n = int(out["count"][c])
        avg = out["sum"][c] / max(n, 1)
        print(f"{c:>8} {n:>9} {avg:>9.1f}m")
    print("stage report:")
    for name, rep in pipe.report().items():
        print(f"  {name:12s} {rep}")
    if args.trace:
        pipe.tracer.export_chrome(args.trace)
        print(f"wrote {args.trace} ({len(pipe.tracer)} spans) — open in "
              f"chrome://tracing or https://ui.perfetto.dev")
    if srv is not None:
        snap = pipe.health_monitor.snapshot()
        print(f"monitor: {snap['pipeline']['windows_total']} windows, "
              f"{snap['pipeline']['dispatches']} device dispatches, "
              f"stages={sorted(snap['stages'])}")
        if args.serve_hold:
            print(f"holding metrics endpoint {args.serve_hold:.0f}s for "
                  f"scrapers...", flush=True)
            time.sleep(args.serve_hold)
        srv.stop()


if __name__ == "__main__":
    main()
