"""Quickstart: the paper's Listing-2 program + the secure pipeline DSL.

Three forms of the same idea, shortest first:

* ``listing2_average_age`` — the paper's RxLua Listing 2 on the
  plaintext Observable layer;
* ``secure_flight_pipeline`` — the DelayedFlights job in 5 fluent lines
  (``repro.dsl.stream``), running under full enclave mode;
* ``secure_flight_pipeline_spec`` — the same job from a declarative
  TOML spec (the paper's Listing-1 shape).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import Observable
from repro.data.synthetic import flight_chunks
from repro.dsl import load_spec, stream


def listing2_average_age():
    """RxLua Listing 2: average age of the adult population — in repro."""
    people_ages = jnp.asarray(
        np.random.default_rng(0).integers(1, 90, 4096).astype(np.float32))
    result = (
        Observable.from_array(people_ages, chunk_rows=512)
        .map(lambda age: age)                      # :map(person.age)
        .filter(lambda age: age > 18)              # :filter(age > 18)
        .reduce(lambda acc, age, m: {               # :reduce(...)
            "sum": acc["sum"] + float(jnp.sum(age * m)),
            "count": acc["count"] + float(jnp.sum(m))},
            init={"sum": 0.0, "count": 0.0})
        .subscribe(
            on_complete=lambda: print("Process complete!"))
    )
    print(f"Adult people average: {result['sum'] / result['count']:.2f}")


def secure_flight_pipeline():
    """map -> filter -> reduce over sealed flight records (enclave mode),
    via the fluent DSL — the paper's few-lines-of-code claim."""
    sb = (stream(flight_chunks(8192, 1024))
          .map("identity", name="sgx_mapper", sgx=True)
          .filter("delay_filter_u32", const=15, name="sgx_filter", sgx=True)
          .reduce("carrier_delay_stats", name="reducer"))
    out = sb.run(mode="enclave")
    worst = int(np.argmax(out["sum"] / np.maximum(out["count"], 1)))
    print(f"delayed flights: {int(out['count'].sum())}; "
          f"worst carrier: #{worst} "
          f"(avg {out['sum'][worst] / max(out['count'][worst], 1):.1f} min)")
    print("stage report:", sb.report())


def secure_flight_pipeline_spec():
    """The same job, declared as a TOML spec (paper Listing 1)."""
    spec = """
    mode = "enclave"
    [stage.sgx_filter]
    op = "delay_filter_u32"
    const = 15
    constraint = "type==sgx"          # the paper's literal spelling
    [stage.reducer]
    reduce = "carrier_delay_stats"
    """
    out = load_spec(spec).run(flight_chunks(8192, 1024))
    print(f"spec form agrees: delayed flights = {int(out['count'].sum())}")


if __name__ == "__main__":
    listing2_average_age()
    secure_flight_pipeline()
    secure_flight_pipeline_spec()
