"""Quickstart: the paper's Listing-2 program + a 3-stage secure pipeline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SecureStreamConfig
from repro.core import Observable, Pipeline, Stage
from repro.data.synthetic import CARRIER_WORD, DELAY_WORD, flight_chunks


def listing2_average_age():
    """RxLua Listing 2: average age of the adult population — in repro."""
    people_ages = jnp.asarray(
        np.random.default_rng(0).integers(1, 90, 4096).astype(np.float32))
    result = (
        Observable.from_array(people_ages, chunk_rows=512)
        .map(lambda age: age)                      # :map(person.age)
        .filter(lambda age: age > 18)              # :filter(age > 18)
        .reduce(lambda acc, age, m: {               # :reduce(...)
            "sum": acc["sum"] + float(jnp.sum(age * m)),
            "count": acc["count"] + float(jnp.sum(m))},
            init={"sum": 0.0, "count": 0.0})
        .subscribe(
            on_complete=lambda: print("Process complete!"))
    )
    print(f"Adult people average: {result['sum'] / result['count']:.2f}")


def secure_flight_pipeline():
    """map -> filter -> reduce over sealed flight records (enclave mode)."""
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid], minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(
            carrier[valid], weights=delay[valid], minlength=20)
        return acc

    pipe = Pipeline(
        [
            Stage("sgx_mapper", op="identity", sgx=True),
            Stage("sgx_filter", op="delay_filter_u32", const=15, sgx=True),
            Stage("reducer", op="custom", reduce_fn=reduce_fn,
                  reduce_init={"count": np.zeros(20), "sum": np.zeros(20)}),
        ],
        SecureStreamConfig(mode="enclave"),
    )
    out = pipe.run(jnp.asarray(c) for c in flight_chunks(8192, 1024))
    worst = int(np.argmax(out["sum"] / np.maximum(out["count"], 1)))
    print(f"delayed flights: {int(out['count'].sum())}; "
          f"worst carrier: #{worst} "
          f"(avg {out['sum'][worst] / max(out['count'][worst], 1):.1f} min)")
    print("stage report:", pipe.report())


if __name__ == "__main__":
    listing2_average_age()
    secure_flight_pipeline()
