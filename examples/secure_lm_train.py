"""End-to-end secure LM training: sealed data pipeline -> train loop ->
sealed checkpoints -> (optional) injected failure + recovery.

Default is a ~20M-param llama-family model that trains a few hundred steps
on CPU; ``--size 100m`` selects a ~100M config (same code path — on a TPU
pod the configs/ entries scale it to the assigned architectures).

Run:  PYTHONPATH=src python examples/secure_lm_train.py --steps 200
      PYTHONPATH=src python examples/secure_lm_train.py --fail-at 50
"""
import argparse

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.dist.meshctx import local_mesh_context
from repro.ft.failures import FailureInjector
from repro.train.trainer import Trainer, TrainerConfig

SIZES = {
    "2m": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
               d_ff=512, vocab_size=2048, head_dim=32),
    "20m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
                d_ff=1536, vocab_size=8192, head_dim=64),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32000, head_dim=64),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="2m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a node failure at this step (0=off)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro-secure-lm")
    args = ap.parse_args()

    cfg = ModelConfig(arch_id=f"secure-lm-{args.size}", family="dense",
                      tie_embeddings=True, **SIZES[args.size])
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", args.seq, args.batch, "train"),
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20),
        remat="none",
    )
    ctx = local_mesh_context()

    # deterministic per-step data => exactly-once replay after recovery
    def data_fn(step: int):
        rng = np.random.default_rng(1000 + step)
        # learnable structure: tokens follow a noisy modular sequence
        start = rng.integers(0, cfg.vocab_size, (args.batch, 1))
        ramp = (start + np.arange(args.seq + 1)[None]) % cfg.vocab_size
        noise = rng.integers(0, cfg.vocab_size, ramp.shape)
        keep = rng.random(ramp.shape) < 0.9
        toks = np.where(keep, ramp, noise).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    injector = FailureInjector(schedule={args.fail_at: "node_loss"}) \
        if args.fail_at else None
    trainer = Trainer(
        run, ctx, data_fn,
        TrainerConfig(total_steps=args.steps, ckpt_every=25, log_every=10,
                      ckpt_dir=args.ckpt_dir, sealed_ckpt=True,
                      sealed_data=True),
        injector=injector)

    print(f"training {cfg.arch_id}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps, sealed data+checkpoints")
    out = trainer.train()
    for h in out["history"]:
        print(f"  step {h['step']:>5}  loss {h['loss']:.4f}  "
              f"{h['sec_per_step'] * 1e3:.0f} ms/step")
    print(f"done: step={out['final_step']} restarts={out['restarts']} "
          f"replayed={out['replayed_steps']} stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
