"""Secure serving: clients attest to the server, establish a session key
via the quote-checked handshake (repro.attest), then send AEAD-sealed
prompt chunks which are opened at ingest, prefilled, and decoded greedily
with a KV cache.

Run:  PYTHONPATH=src python examples/secure_serve.py --requests 4 --new 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.attest.directory import KeyDirectory
from repro.attest.measure import IO_ENDPOINT, measure_bytes
from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.enclave import egress, ingress
from repro.dist.meshctx import local_mesh_context
from repro.models import api
from repro.serve.engine import make_decode_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = ModelConfig(arch_id="serve-demo", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
                      vocab_size=2048, head_dim=32, tie_embeddings=True)
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", args.prompt_len,
                                      args.requests, "decode"),
                    optimizer=OptimizerConfig())
    ctx = local_mesh_context()
    params = api.init_params(cfg, jax.random.key(0))

    # --- attestation + key establishment (the paper's assumed bootstrap):
    # the serving enclave is measured and allowlisted; the client verifies
    # its quote during the handshake and the session key seals requests.
    directory = KeyDirectory(seed=7)
    server_m = measure_bytes(b"serve-enclave", cfg.arch_id.encode())
    directory.enroll("server", server_m, allow=True)
    directory.enroll("client", IO_ENDPOINT, allow=True)
    key = directory.establish("client-requests", "client", "server",
                              stage_id=0)
    print(f"attested session established (measurement "
          f"{server_m.hex()[:16]}..., epoch {directory.epoch})")
    rng = np.random.default_rng(0)
    prompts_np = rng.integers(0, cfg.vocab_size,
                              (args.requests, args.prompt_len),
                              dtype=np.int32)
    sealed = ingress("encrypted", key, 0, jnp.asarray(prompts_np))
    prompts, ok = egress("encrypted", key, sealed)
    assert bool(ok), "request MAC failure"
    print(f"ingested {args.requests} sealed prompts "
          f"({prompts.shape[1]} tokens each), MAC ok")

    # --- prefill + greedy decode
    max_seq = args.prompt_len + args.new
    t0 = time.perf_counter()
    logits, cache = api.prefill(cfg, params, {"tokens": prompts}, ctx,
                                max_seq=max_seq)
    decode = jax.jit(make_decode_step(run, ctx), donate_argnums=(3,))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = [tok]
    pos = jnp.int32(args.prompt_len)
    for i in range(args.new - 1):
        tok, _, cache = decode(params, tok, pos, cache)
        outs.append(tok)
        pos = pos + 1
    gen = jnp.concatenate(outs, axis=1)
    dt = time.perf_counter() - t0
    tps = args.requests * args.new / dt
    print(f"generated {args.new} tokens x {args.requests} requests "
          f"in {dt:.2f}s ({tps:.1f} tok/s)")
    for r in range(min(args.requests, 2)):
        print(f"  req{r}: ...{list(np.asarray(prompts)[r][-4:])} -> "
              f"{list(np.asarray(gen)[r][:8])}...")


if __name__ == "__main__":
    main()
