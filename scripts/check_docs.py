"""Docs sanity gate (CI `docs` job): link resolution + fence syntax.

Checks, without importing jax or executing anything:

* every *internal* markdown link in docs/*.md and README.md resolves —
  the file exists, and when the link carries a ``#fragment`` a matching
  heading exists in the target (GitHub slug rules: lowercase, spaces to
  dashes, punctuation dropped);
* every fenced ``python`` block parses (``compile``), including blocks
  marked ``skip``;
* every fence is terminated.

Execution of the runnable blocks is the separate, heavier
``tests/test_docs_examples.py`` (needs jax).  Exits non-zero with a
per-finding report on any failure.

Run:  python scripts/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def github_slug(heading: str) -> str:
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\s-]", "", h, flags=re.UNICODE)
    return re.sub(r"\s+", "-", h.strip())


def headings_of(path: Path) -> set:
    out = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if line.strip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if m:
            out.add(github_slug(m.group(1)))
    return out


def strip_fences(text: str):
    """Yield (line_no, line) for lines outside fenced blocks."""
    in_fence = False
    for i, line in enumerate(text.splitlines(), 1):
        if line.strip().startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield i, line


def check_links(path: Path, problems: list) -> None:
    for ln, line in strip_fences(path.read_text()):
        for target in _LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, frag = target.partition("#")
            dest = (path.parent / file_part).resolve() if file_part \
                else path
            if file_part and not dest.exists():
                problems.append(f"{path.relative_to(ROOT)}:{ln}: broken "
                                f"link target {target!r}")
                continue
            if frag and dest.suffix == ".md":
                if github_slug(frag) not in headings_of(dest):
                    problems.append(
                        f"{path.relative_to(ROOT)}:{ln}: link anchor "
                        f"#{frag} not found in {dest.name}")


def extract_fenced_blocks(path: Path):
    """THE fenced-block scanner — single definition shared by this
    syntax gate and ``tests/test_docs_examples.py`` (which imports it),
    so 'what counts as a fenced block' cannot drift between the two.

    -> ([(lang, info, code, first_line_no)], problems): ``lang`` is the
    fence's language tag (lowercased, "" for untyped), ``info`` the rest
    of the info string (e.g. ``skip``); an unterminated fence is a
    problem, not a block.
    """
    blocks, problems = [], []
    lang = info = None
    buf: list = []
    start = 0
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = _FENCE.match(line.strip())
        if m and lang is None:
            lang = m.group(1).lower()
            info = m.group(2).strip().lower()
            buf, start = [], i + 1
        elif line.strip() == "```" and lang is not None:
            blocks.append((lang, info, "\n".join(buf), start))
            lang = info = None
        elif lang is not None:
            buf.append(line)
    if lang is not None:
        problems.append(f"{path.name}:{start}: unterminated ``` fence")
    return blocks, problems


def check_fences(path: Path, problems: list) -> None:
    blocks, fence_problems = extract_fenced_blocks(path)
    problems.extend(f"{path.relative_to(ROOT)}{p[p.index(':'):]}"
                    for p in fence_problems)
    for lang, _info, code, start in blocks:
        if lang != "python":
            continue
        try:
            compile(code, f"{path.name}:{start}", "exec")
        except SyntaxError as e:
            problems.append(
                f"{path.relative_to(ROOT)}:{start}: python fence "
                f"does not parse: {e.msg} (line {e.lineno})")


def main() -> int:
    problems: list = []
    for path in DOC_FILES:
        if not path.exists():
            problems.append(f"missing doc file: {path.relative_to(ROOT)}")
            continue
        check_links(path, problems)
        check_fences(path, problems)
    for guide in ("architecture", "security-model", "dsl", "benchmarks",
                  "observability", "fault-tolerance"):
        if not (ROOT / "docs" / f"{guide}.md").exists():
            problems.append(f"required guide missing: docs/{guide}.md")
    if problems:
        print(f"check_docs: {len(problems)} problem(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"check_docs: OK ({len(DOC_FILES)} files, links + fences clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
