"""Prometheus text-exposition well-formedness gate (CI scrape check).

Validates a scraped ``/metrics`` body against the text exposition format
(version 0.0.4), stdlib-only:

* every non-comment line is ``name[{labels}] value`` with a legal metric
  name, legal label names, correctly quoted/escaped label values, and a
  value that parses as a float (``NaN``/``Inf`` allowed);
* every sample's base name was declared by a preceding ``# TYPE`` line
  (``_count``/``_sum`` suffixes resolve to their summary's base name)
  and no metric name is ``# TYPE``-declared twice;
* with ``--require-label k=v``, at least one sample carries that label
  pair (CI asserts presence of the per-stage series this way).

Run:  python scripts/check_prometheus.py metrics.prom \
          [--require-label stage=ingress] [--min-samples N]

Exits non-zero with a per-finding report on any violation.  Also
importable: ``validate(text) -> list[str]`` returns the findings.
"""
from __future__ import annotations

import re
import sys
from typing import List, Optional, Tuple

_METRIC = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABEL = r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\.)*"'
_VALUE = r"(?:[-+]?(?:\d+\.?\d*|\.\d+)(?:[eE][-+]?\d+)?|NaN|[-+]?Inf)"
_SAMPLE = re.compile(
    rf"^(?P<name>{_METRIC})"
    rf"(?:\{{(?P<labels>{_LABEL}(?:,{_LABEL})*)?\}})?"
    rf" (?P<value>{_VALUE})(?: \d+)?$")
_HELP = re.compile(rf"^# HELP ({_METRIC}) .*$")
_TYPE = re.compile(
    rf"^# TYPE ({_METRIC}) (counter|gauge|summary|histogram|untyped)$")
_LABEL_ONE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\\n]|\\.)*)"')


def _base_name(name: str) -> str:
    """Resolve summary/histogram child samples to their declared base."""
    for suffix in ("_count", "_sum", "_bucket"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def validate(text: str,
             require_labels: Tuple[Tuple[str, str], ...] = (),
             min_samples: int = 1) -> List[str]:
    """-> list of findings (empty = well-formed)."""
    problems: List[str] = []
    typed: set = set()
    n_samples = 0
    satisfied = {pair: False for pair in require_labels}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            if _HELP.match(line):
                continue
            m = _TYPE.match(line)
            if m:
                if m.group(1) in typed:
                    problems.append(
                        f"line {ln}: duplicate # TYPE for {m.group(1)!r}")
                typed.add(m.group(1))
                continue
            problems.append(f"line {ln}: malformed comment: {line!r}")
            continue
        m = _SAMPLE.match(line)
        if m is None:
            problems.append(f"line {ln}: malformed sample: {line!r}")
            continue
        n_samples += 1
        name = m.group("name")
        if _base_name(name) not in typed and name not in typed:
            problems.append(
                f"line {ln}: sample {name!r} has no preceding # TYPE")
        for lname, lval in _LABEL_ONE.findall(m.group("labels") or ""):
            for pair in require_labels:
                if (lname, lval) == pair:
                    satisfied[pair] = True
    if n_samples < min_samples:
        problems.append(
            f"only {n_samples} samples, expected >= {min_samples}")
    for (k, v), ok in satisfied.items():
        if not ok:
            problems.append(f'no sample carries the label {k}="{v}"')
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="scraped /metrics body to validate")
    ap.add_argument("--require-label", action="append", default=[],
                    metavar="K=V", help="require a sample with label K=V")
    ap.add_argument("--min-samples", type=int, default=1)
    args = ap.parse_args(argv)
    pairs = []
    for spec in args.require_label:
        k, _, v = spec.partition("=")
        pairs.append((k, v))
    with open(args.path) as f:
        text = f.read()
    problems = validate(text, tuple(pairs), args.min_samples)
    for p in problems:
        print(f"check_prometheus: {p}", file=sys.stderr)
    if problems:
        return 1
    print(f"check_prometheus: OK ({args.path}: "
          f"{len(text.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
