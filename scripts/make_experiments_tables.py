"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from dryrun JSONs."""
import glob
import json
import os
import sys

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def main():
    recs = [json.load(open(f)) for f in sorted(glob.glob(f"{DRY}/*.json"))]
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    archs, shapes = [], []
    for r in recs:
        if r["arch"] not in archs:
            archs.append(r["arch"])
        if r["shape"] not in shapes:
            shapes.append(r["shape"])

    print("### Single-pod (16x16 = 256 chips) roofline table\n")
    print("| arch | shape | status | compile_s | mem/chip GB | t_compute s "
          "| t_mem s | t_coll s | dominant | MODEL_FLOPS/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r = by.get((a, s, "pod16x16"))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | SKIP (spec) | — | — | — | — | — | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | ERROR | — | — | — | — | — | — | — | — |")
                continue
            rf = r["roofline"]
            mem = r["memory"]["peak_estimate_bytes"] / 1e9
            print(f"| {a} | {s} | ok | {r['compile_s']:.0f} | {mem:.1f} "
                  f"| {rf['t_compute_s']:.3g} | {rf['t_mem_s']:.3g} "
                  f"| {rf['t_coll_s']:.3g} | {rf['dominant'][2:]} "
                  f"| {rf['useful_flops_ratio']:.2f} "
                  f"| {rf['roofline_fraction']:.3f} |")

    print("\n### Multi-pod (2x16x16 = 512 chips) dry-run\n")
    print("| arch | shape | status | compile_s | mem/chip GB | collectives "
          "(per-chip bytes by kind) |")
    print("|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r = by.get((a, s, "pod2x16x16"))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | SKIP (spec) | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | ERROR | — | — | — |")
                continue
            mem = r["memory"]["peak_estimate_bytes"] / 1e9
            kinds = r["roofline"]["collective_by_kind"]
            ks = " ".join(f"{k.split('-')[-1]}={v / 1e9:.2g}GB"
                          for k, v in sorted(kinds.items()))
            print(f"| {a} | {s} | ok | {r['compile_s']:.0f} | {mem:.1f} "
                  f"| {ks} |")


if __name__ == "__main__":
    main()
