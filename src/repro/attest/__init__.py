"""repro.attest: attestation & key lifecycle (quotes, handshake, epochs).

The trust bootstrap SecureStreams assumes pre-done (§4): simulated
enclave measurements and quotes (`measure`, `quote`), an attested DH
handshake (`handshake`), the `KeyDirectory` that owns every live session
key (`directory`), and the epoch ratchet + rotation policy (`rotation`).
"""
from repro.attest.directory import (EdgeHandle, KeyDirectory,
                                    KeyDirectoryError, NoSessionError,
                                    RevokedWorkerError, SessionState,
                                    ephemeral_edge_key)
from repro.attest.handshake import HandshakeEnd, HandshakeError
from repro.attest.measure import (IO_ENDPOINT, measure_bytes, measure_fn,
                                  measure_stage)
from repro.attest.quote import (Quote, QuoteError, QuotePolicy, QuotingKey,
                                verify_quote)
from repro.attest.rotation import hkdf_sha256, key_from_bytes, ratchet_key

__all__ = [
    "EdgeHandle", "KeyDirectory", "KeyDirectoryError", "NoSessionError",
    "RevokedWorkerError", "SessionState", "ephemeral_edge_key",
    "HandshakeEnd", "HandshakeError",
    "IO_ENDPOINT", "measure_bytes", "measure_fn", "measure_stage",
    "Quote", "QuoteError", "QuotePolicy", "QuotingKey", "verify_quote",
    "hkdf_sha256", "key_from_bytes", "ratchet_key",
]
