"""KeyDirectory: the single owner of live session keys, epochs, counters.

This is the trust-bootstrap layer the paper assumes away ("we assume that
attestation and key establishment was previously performed", §4).  Every
sealed path in the repo — `core.secure_channel`, `core.enclave`,
`dist.collectives`, `dist.pipeline_parallel`, `core.pipeline` — obtains
its :class:`repro.crypto.keys.StageKey` from a directory edge, never from
`derive_stage_key` (a grep test enforces this).  The directory:

* enrolls worker identities (id + measurement) and issues/verifies their
  quotes against a :class:`repro.attest.quote.QuotePolicy`;
* establishes per-edge session keys via the attested DH handshake
  (`repro.attest.handshake`) — both endpoints are quote-checked;
* owns the epoch counter: :meth:`advance_epoch` ratchets every live edge
  key (`repro.attest.rotation`) and zeroes its chunk counter, keeping a
  bounded history so in-flight chunks sealed in epoch N still open after
  the flip to N+1;
* revokes workers live: :meth:`revoke` quarantines an id (its quotes stop
  verifying, pools skip it) and tears down any session it terminates;
* owns the trust domain's **security audit log**
  (:class:`repro.obs.audit.AuditLog`): rekeys, revocations, quote
  rejections, and nonce-space exhaustion are recorded in stream order as
  they happen — the engine appends its data-plane events (MAC failures,
  evictions) and any attached :class:`repro.obs.monitor.Watchdog`
  appends its health verdicts (``slo_breach``/``stall``) to the same
  log, so one ordered stream covers the run end to end.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.attest.handshake import HandshakeEnd, HandshakeError
from repro.attest.quote import (Quote, QuoteError, QuotePolicy, QuotingKey,
                                verify_quote)
from repro.attest.rotation import key_from_bytes, ratchet_key
from repro.crypto.keys import (NONCE_COUNTER_MAX, NonceExhaustedError,
                               StageKey)
from repro.obs.audit import AuditLog


class KeyDirectoryError(RuntimeError):
    """Any directory-level failure (enrollment, admission, counters)."""


class NoSessionError(KeyDirectoryError):
    """An edge has no established (or no longer drainable) session."""


class RevokedWorkerError(KeyDirectoryError):
    """A quarantined worker id was used where trust is required."""

    def __init__(self, worker_id: str, detail: str = ""):
        super().__init__(f"worker {worker_id!r} is revoked"
                         + (f": {detail}" if detail else ""))
        self.worker_id = worker_id


@dataclass
class SessionState:
    """One edge's live session: current key + drainable epoch history."""
    edge: str
    left: str                    # worker ids of the two endpoints
    right: str
    transcript: bytes
    epoch: int
    chunks: int = 0              # sealed-chunk counter, reset per epoch
    keys: Dict[int, StageKey] = field(default_factory=dict)  # epoch -> key

    def key_at(self, epoch: int) -> StageKey:
        """This edge's key at ``epoch`` — chunks always open/re-seal
        under their *ingress* epoch (epoch-local counters; a later
        epoch's key would replay its (key, nonce) pairs).  Raises
        :class:`NoSessionError` once history has pruned the epoch."""
        k = self.keys.get(epoch)
        if k is None:
            raise NoSessionError(
                f"edge {self.edge!r} has no key for epoch {epoch} "
                f"(live: {sorted(self.keys)}) — drained past history")
        return k


@dataclass
class EdgeHandle:
    """A capability-style view of one directory edge, passed to sealing
    code instead of a raw StageKey so rotation is picked up live."""
    directory: "KeyDirectory"
    edge: str

    def key(self, epoch: Optional[int] = None) -> StageKey:
        """The edge's live key (or its key at a past, undrained epoch)."""
        return self.directory.edge_key(self.edge, epoch=epoch)

    @property
    def epoch(self) -> int:
        """The edge's current epoch (advances on every rotation)."""
        return self.directory.session(self.edge).epoch

    def next_counter(self) -> int:
        """Allocate the next managed chunk counter (epoch-local)."""
        return self.directory.next_counter(self.edge)

    def next_counters(self, n: int) -> int:
        """Reserve ``n`` contiguous counters, returning the first — a
        consumer sealing n items per round MUST take the whole block
        (see :meth:`KeyDirectory.next_counters`)."""
        return self.directory.next_counters(self.edge, n)

    def reserve_window(self, n: int) -> "Tuple[int, int]":
        """Atomically reserve a contiguous ``n``-counter block AND snapshot
        the epoch it belongs to: ``(base, epoch)`` — counters base..base+n-1
        are valid only under that epoch's key (counters are epoch-local).
        The window-batched engine reserves one block per sealed window,
        mirroring how ``secure_exchange`` reserves its W^2 nonce block, so
        co-consumers of an edge can never land inside the window's block.
        """
        return (self.directory.next_counters(self.edge, n),
                self.directory.session(self.edge).epoch)


class KeyDirectory:
    """Attestation verifier + key-establishment service + key store."""

    def __init__(self, seed: int = 0, policy: Optional[QuotePolicy] = None,
                 *, epoch_history: int = 8,
                 audit: Optional[AuditLog] = None):
        self.seed = seed
        self.policy = policy if policy is not None else QuotePolicy()
        # THE security audit log of this trust domain: lifecycle events
        # are recorded here by the directory itself; the streaming engine
        # appends its data-plane events (mac_failure, eviction) so one
        # in-order stream covers the whole run.
        self.audit = audit if audit is not None else AuditLog()
        self.epoch = 0
        self.epoch_history = max(1, int(epoch_history))
        self.clock = 0                       # logical time for quote ages
        self._qk = QuotingKey.from_seed(seed)
        self._rng = random.Random(f"repro-attest-{seed}")
        self._workers: Dict[str, bytes] = {}       # id -> measurement
        self._sessions: Dict[str, SessionState] = {}
        # Admission interceptor: callable(worker_id) -> rejection reason
        # or None.  Consulted by admit() BEFORE the quote round-trip so a
        # fault injector (repro.ft.chaos) can make a live enrollment fail
        # through the real admission path — the rejection lands in the
        # audit log as a genuine quote_rejected event.  None in
        # production.
        self.admission_interceptor = None

    # ------------------------------------------------------------ clock

    def tick(self, n: int = 1) -> int:
        """Advance the logical clock quote freshness is judged against."""
        self.clock += n
        return self.clock

    # ------------------------------------------------- worker lifecycle

    def enroll(self, worker_id: str, measurement: bytes, *,
               allow: bool = False) -> None:
        """Register a worker identity.  Enrollment does NOT grant trust:
        admission happens when its quote verifies against the policy
        (``allow=True`` additionally allowlists the measurement — the
        operator's provisioning step)."""
        prev = self._workers.get(worker_id)
        if prev is not None and prev != measurement:
            raise KeyDirectoryError(
                f"worker {worker_id!r} re-enrolled with a different "
                f"measurement — identities are immutable")
        self._workers[worker_id] = measurement
        if allow:
            self.policy.allow(measurement)

    def quote_for(self, worker_id: str, report_data: bytes = b"") -> Quote:
        """The worker's quoting enclave: a fresh signed quote over its
        enrolled measurement, bound to ``report_data``."""
        m = self._workers.get(worker_id)
        if m is None:
            raise KeyDirectoryError(f"unknown worker {worker_id!r}")
        return self._qk.quote(worker_id, m, report_data, now=self.clock)

    def verify(self, q: Quote,
               expect_report_data: Optional[bytes] = None) -> None:
        """Check a quote against the policy (allowlist, freshness,
        revocation, report-data binding); raises on any failure —
        revoked ids surface as :class:`RevokedWorkerError`."""
        try:
            verify_quote(self._qk, q, self.policy, now=self.clock,
                         expect_report_data=expect_report_data)
        except QuoteError as e:
            self.audit.record("quote_rejected", worker=q.worker_id,
                              reason=e.reason)
            if e.reason == "revoked":
                raise RevokedWorkerError(q.worker_id, str(e)) from e
            raise

    def admit(self, worker_id: str) -> Quote:
        """Quote-then-verify gate; raises on rejection, returns the quote.

        If an ``admission_interceptor`` is installed (fault injection),
        it is consulted first: a returned reason string fails the
        handshake through the same audit path as a bad quote."""
        icpt = self.admission_interceptor
        if icpt is not None:
            reason = icpt(worker_id)
            if reason is not None:
                self.audit.record("quote_rejected", worker=worker_id,
                                  reason=reason)
                raise QuoteError(reason, worker_id)
        q = self.quote_for(worker_id)
        self.verify(q)
        return q

    def is_admitted(self, worker_id: str) -> bool:
        """Non-raising :meth:`admit` (pool-membership checks)."""
        try:
            self.admit(worker_id)
            return True
        except (QuoteError, KeyDirectoryError):
            return False

    # ------------------------------------------------------- sessions

    def _end(self, worker_id: str, context: bytes) -> HandshakeEnd:
        return HandshakeEnd(
            quote_fn=lambda rd: self.quote_for(worker_id, rd),
            verify_fn=lambda q, rd: self.verify(q, expect_report_data=rd),
            secret=self._rng.randrange(2, 1 << 255),
            context=context)

    def establish(self, edge: str, left: str, right: str, *,
                  stage_id: Optional[int] = None) -> StageKey:
        """Run the attested handshake between two enrolled workers and
        install the resulting session key for ``edge``.

        Both flights carry quotes; both ends verify before deriving, so a
        revoked or unallowlisted endpoint cannot obtain (or grant) key
        material.  Re-establishing an existing edge replaces its session
        (the re-handshake path after revocation/recovery).
        """
        if left == right:
            raise KeyDirectoryError(
                f"edge {edge!r} needs two distinct endpoints, got {left!r}")
        context = b"|".join([b"ss-edge", edge.encode(),
                             left.encode(), right.encode()])
        a, b = self._end(left, context), self._end(right, context)
        fa, fb = a.flight(), b.flight()
        mat_a, tr_a = a.derive(fa, fb)        # left verifies right's quote
        mat_b, tr_b = b.derive(fb, fa)        # right verifies left's quote
        if mat_a != mat_b or tr_a != tr_b:    # DH agreement is exact
            raise HandshakeError(f"key agreement failed on edge {edge!r}")
        sid = stage_id if stage_id is not None else len(self._sessions)
        key = key_from_bytes(mat_a, sid)
        # born in the current epoch; older epochs predate the session
        st = SessionState(edge=edge, left=left, right=right,
                          transcript=tr_a, epoch=self.epoch,
                          keys={self.epoch: key})
        self._sessions[edge] = st
        self.tick()
        return key

    def has_session(self, edge: str) -> bool:
        """True if ``edge`` has a live established session."""
        return edge in self._sessions

    def session(self, edge: str) -> SessionState:
        """The edge's live :class:`SessionState`; raises
        :class:`NoSessionError` before :meth:`establish` has run."""
        st = self._sessions.get(edge)
        if st is None:
            raise NoSessionError(
                f"no established session for edge {edge!r} — run "
                f"KeyDirectory.establish (attested handshake) first")
        return st

    def edge_key(self, edge: str, *, epoch: Optional[int] = None) -> StageKey:
        """The edge's session key at ``epoch`` (current when None)."""
        st = self.session(edge)
        return st.key_at(st.epoch if epoch is None else epoch)

    def handle(self, edge: str) -> EdgeHandle:
        """Capability view of an established edge — what sealing code
        holds instead of a raw key, so rotation is picked up live."""
        self.session(edge)                    # must exist
        return EdgeHandle(self, edge)

    def next_counter(self, edge: str) -> int:
        """Allocate the next chunk counter for an edge (epoch-local; the
        StageKey nonce guard backstops wraparound)."""
        return self.next_counters(edge, 1)

    def next_counters(self, edge: str, n: int) -> int:
        """Allocate a contiguous block of ``n`` counters and return the
        first.  A consumer that seals n items per round (secure_exchange
        seals W² blocks) MUST reserve all n — allocating one and deriving
        the rest would collide with the edge's other consumers."""
        if n < 1:
            raise KeyDirectoryError(f"counter block size must be >= 1: {n}")
        st = self.session(edge)
        if st.chunks + n - 1 > NONCE_COUNTER_MAX:
            self.audit.record("nonce_exhausted", edge=edge, epoch=st.epoch,
                              chunks=st.chunks, requested=n)
            raise NonceExhaustedError(
                f"edge {edge!r} would exhaust its nonce space at epoch "
                f"{st.epoch}: {st.chunks} counters used, {n} requested "
                f"(max {NONCE_COUNTER_MAX}) — advance_epoch to reset")
        c = st.chunks
        st.chunks += n
        return c

    def edges(self) -> List[str]:
        """Names of every edge with a live session."""
        return list(self._sessions)

    # ------------------------------------------------------- rotation

    def advance_epoch(self) -> int:
        """Ratchet every live session key to the next epoch and zero its
        chunk counter.  Keys older than ``epoch_history`` epochs are
        dropped (forward secrecy: drained traffic stays sealed)."""
        self.epoch += 1
        for st in self._sessions.values():
            st.keys[self.epoch] = ratchet_key(
                st.key_at(st.epoch), epoch=self.epoch,
                transcript=st.transcript)
            st.epoch = self.epoch
            st.chunks = 0
            for e in [e for e in st.keys
                      if e <= self.epoch - self.epoch_history]:
                del st.keys[e]
        self.audit.record("rekey", epoch=self.epoch,
                          edges=len(self._sessions))
        self.tick()
        return self.epoch

    # ------------------------------------------------------ revocation

    def revoke(self, worker_id: str) -> List[str]:
        """Quarantine a worker: its quotes stop verifying (pools must
        skip it) and every session it terminates is torn down.  Returns
        the edges dropped so the caller can re-handshake survivors.

        Unknown ids are rejected: silently "revoking" a typo'd id would
        leave the real worker processing chunks with no error anywhere.
        """
        if worker_id not in self._workers:
            raise KeyDirectoryError(
                f"cannot revoke unknown worker {worker_id!r} — enrolled "
                f"ids look like {sorted(self._workers)[:4]}")
        self.policy.revoked.add(worker_id)
        dropped = [e for e, st in self._sessions.items()
                   if worker_id in (st.left, st.right)]
        for e in dropped:
            del self._sessions[e]
        self.audit.record("revocation", worker=worker_id,
                          edges=list(dropped))
        self.tick()
        return dropped

    def reestablish(self, edge: str, left: str, right: str, *,
                    stage_id: Optional[int] = None) -> StageKey:
        """Recovery-path re-handshake on a surviving endpoint pair (both
        are re-verified; a revoked survivor still fails)."""
        return self.establish(edge, left, right, stage_id=stage_id)


def ephemeral_edge_key(label: str = "edge", *, seed: int = 0,
                       stage_id: int = 0) -> StageKey:
    """A session key from a throwaway directory (tests/benchmarks): two
    endpoints enrolled, allowlisted, and handshaken — the one sanctioned
    shortcut to a StageKey outside a long-lived directory."""
    from repro.attest.measure import IO_ENDPOINT
    d = KeyDirectory(seed=seed)
    d.enroll(f"{label}/a", IO_ENDPOINT, allow=True)
    d.enroll(f"{label}/b", IO_ENDPOINT, allow=True)
    return d.establish(label, f"{label}/a", f"{label}/b", stage_id=stage_id)
