"""Authenticated key establishment: quote-checked DH, transcript-bound.

The shape is the SGX remote-attestation handshake (SP 800-56A-style
unified model, as SecureCloud's key-provisioning service runs it): each
side generates an ephemeral DH share, obtains a quote whose
``report_data`` is a hash binding that share to the session context, and
verifies the peer's quote *before* deriving anything.  The session key is
HKDF(DH shared secret, salt=transcript), where the transcript hashes the
context, both public shares, and both quote signatures — so a
man-in-the-middle who substitutes a share invalidates the quote binding,
and a quote replayed from another session fails the report_data check.

The group is RFC 3526 MODP-2048 over Python ints (an X25519-style
ephemeral-ephemeral exchange built from hashlib/bigint primitives only —
the container has no curve library, and the handshake is a control-plane
cost, not a data-plane one).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Tuple

from repro.attest.quote import Quote
from repro.attest.rotation import hkdf_sha256

# RFC 3526 group 14 (2048-bit MODP); generator 2.
DH_PRIME = int(
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74"
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437"
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED"
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05"
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB"
    "9ED529077096966D670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B"
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9DE2BCBF695581718"
    "3995497CEA956AE515D2261898FA051015728E5A8AACAA68FFFFFFFFFFFFFFFF",
    16)
DH_GENERATOR = 2
_PUB_BYTES = 256


class HandshakeError(RuntimeError):
    pass


@dataclass(frozen=True)
class HandshakeMessage:
    """One side's flight: ephemeral DH public share + binding quote."""
    pub: int
    quote: Quote


def bind_share(context: bytes, pub: int) -> bytes:
    """report_data binding a DH share to this session's context."""
    return hashlib.sha256(b"ss-hs-bind|" + context +
                          pub.to_bytes(_PUB_BYTES, "big")).digest()


def _transcript(context: bytes, a: HandshakeMessage,
                b: HandshakeMessage) -> bytes:
    """Order-canonical transcript hash (both ends compute it identically
    without role bookkeeping): context, then flights sorted by share."""
    lo, hi = sorted((a, b), key=lambda m: m.pub)
    h = hashlib.sha256()
    h.update(b"ss-hs-transcript|" + context)
    for m in (lo, hi):
        h.update(m.pub.to_bytes(_PUB_BYTES, "big"))
        h.update(m.quote.signature)
    return h.digest()


class HandshakeEnd:
    """One endpoint of the handshake.

    ``quote_fn(report_data) -> Quote`` asks this worker's quoting enclave
    for a fresh quote over the given binding; ``verify_fn(quote,
    expect_report_data)`` applies the verifier policy to the peer's quote
    and must raise on rejection (repro.attest.quote.verify_quote via the
    KeyDirectory).  ``secret`` is the ephemeral DH exponent (the caller's
    RNG decides determinism).
    """

    def __init__(self, *, quote_fn: Callable[[bytes], Quote],
                 verify_fn: Callable[[Quote, bytes], None],
                 secret: int, context: bytes = b""):
        if not 1 < secret < DH_PRIME - 1:
            raise HandshakeError("ephemeral secret out of range")
        self._quote_fn = quote_fn
        self._verify_fn = verify_fn
        self._x = secret
        self.context = context
        self.pub = pow(DH_GENERATOR, secret, DH_PRIME)

    def flight(self) -> HandshakeMessage:
        return HandshakeMessage(
            pub=self.pub,
            quote=self._quote_fn(bind_share(self.context, self.pub)))

    def derive(self, mine: HandshakeMessage,
               peer: HandshakeMessage) -> Tuple[bytes, bytes]:
        """Verify the peer and derive -> (key material 32B, transcript).

        Raises :class:`HandshakeError` / the verify_fn's QuoteError on a
        substituted share, a replayed quote, or a policy rejection —
        nothing is derived from an unverified peer.
        """
        if not 1 < peer.pub < DH_PRIME - 1:
            raise HandshakeError("peer share out of range")
        if peer.pub == self.pub:
            raise HandshakeError("reflected share")
        self._verify_fn(peer.quote, bind_share(self.context, peer.pub))
        shared = pow(peer.pub, self._x, DH_PRIME)
        transcript = _transcript(self.context, mine, peer)
        key = hkdf_sha256(shared.to_bytes(_PUB_BYTES, "big"),
                          salt=transcript, info=b"ss-session-key")
        return key, transcript
