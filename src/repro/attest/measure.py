"""Enclave measurements: deterministic identity of the code a worker runs.

SGX's MRENCLAVE is a hash of the enclave's initial memory contents; the
simulated equivalent here is a SHA-256 over the *stage definition* — the
operator name / constant for static-registry ops, or the compiled code
object of a custom fn (bytecode + consts + names, NOT the source file
path, so the same lambda measured in two processes agrees).  A worker is
admitted to key material only if its measurement is on the verifier's
allowlist (repro.attest.quote.QuotePolicy), which is what turns the
paper's "we assume attestation was previously performed" into an actual
check: change one constant in a stage fn and its quote stops verifying.
"""
from __future__ import annotations

import hashlib
import types
from typing import Callable, Optional

MEASUREMENT_LEN = 32


def measure_bytes(*parts: bytes) -> bytes:
    """SHA-256 over length-prefixed parts (order- and boundary-sensitive)."""
    h = hashlib.sha256()
    for p in parts:
        h.update(len(p).to_bytes(8, "little"))
        h.update(p)
    return h.digest()


def _measure_code(code: types.CodeType) -> bytes:
    """Canonical hash of a code object, recursing into nested code
    consts — ``repr`` of a nested code object embeds its memory address,
    which would make byte-identical definitions measure differently."""
    const_parts = []
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            const_parts.append(b"code:" + _measure_code(c))
        else:
            const_parts.append(repr(c).encode())
    return measure_bytes(
        b"code",
        code.co_code,
        measure_bytes(*const_parts),
        repr(code.co_names).encode(),
        repr(code.co_varnames[:code.co_argcount]).encode(),
    )


def _value_bytes(v) -> bytes:
    """Canonical bytes of a captured value.  Array-likes hash their full
    contents (dtype + shape + buffer) — ``repr`` elides interior elements
    of large arrays, which would let differently-tampered weights measure
    identically."""
    if hasattr(v, "dtype") and hasattr(v, "shape"):
        import numpy as np
        a = np.asarray(v)
        return measure_bytes(b"nd", str(a.dtype).encode(),
                             repr(a.shape).encode(), a.tobytes())
    return repr(v).encode()


def measure_fn(fn: Callable) -> bytes:
    """Measurement of a Python callable: code object + captured state.

    Hashes the bytecode + consts (nested code objects measured
    recursively) + names + argcount, AND the function's defaults and
    closure-cell values (full array contents, not reprs) — a stage fn
    whose behavior depends on a captured variable must re-measure when
    that value changes, or a tampered worker would keep verifying.
    Stable across processes for the same definition + captures.
    """
    code = getattr(fn, "__code__", None)
    if code is None:  # builtins / partials: fall back to repr identity
        return measure_bytes(b"callable", repr(fn).encode())
    parts = [b"fn", _measure_code(code)]
    for dflt in getattr(fn, "__defaults__", None) or ():
        parts.append(_value_bytes(dflt))
    for cell in getattr(fn, "__closure__", None) or ():
        try:
            parts.append(_value_bytes(cell.cell_contents))
        except ValueError:          # empty cell (still-unbound name)
            parts.append(b"<empty-cell>")
    return measure_bytes(*parts)


def measure_stage(*, op: str = "custom", const: float = 0.0,
                  fn: Optional[Callable] = None, sgx: bool = True) -> bytes:
    """Measurement of one pipeline stage (repro.core.pipeline.Stage).

    Static-registry stages are measured by (op, const); custom stages by
    the code hash of their fn.  The sgx placement bit is part of the
    identity — moving a stage out of the enclave changes what you attest.
    """
    parts = [b"stage", op.encode(), repr(float(const)).encode(),
             b"sgx" if sgx else b"plain"]
    if fn is not None:
        parts.append(measure_fn(fn))
    return measure_bytes(*parts)


# Trusted I/O endpoints (pipeline ingress/egress, data sources) have no
# operator code; they attest a fixed identity.
IO_ENDPOINT = measure_bytes(b"io-endpoint")
