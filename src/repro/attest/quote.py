"""Simulated SGX quotes: signed (worker, measurement, report_data) claims.

A real deployment would call the Quoting Enclave and verify via IAS/DCAP;
here the quoting key is a software HMAC secret shared between the QE and
the verifier (standing in for the EPID/ECDSA group key — see the README
"Attestation & trust model" section for exactly what this does and does
not prove).  Everything *around* the signature is real: measurements are
allowlisted, quotes expire against a logical clock, revoked worker ids
are rejected, and ``report_data`` binds a quote to one handshake's DH
public value so a quote cannot be replayed into a different session.
"""
from __future__ import annotations

import hashlib
import hmac
from dataclasses import dataclass, field
from typing import Optional, Set


class QuoteError(RuntimeError):
    """Quote failed verification; ``reason`` is a stable machine tag."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"quote rejected ({reason})"
                         + (f": {detail}" if detail else ""))
        self.reason = reason


@dataclass(frozen=True)
class Quote:
    worker_id: str
    measurement: bytes          # repro.attest.measure digest
    report_data: bytes          # caller-bound data (e.g. H(DH pub))
    issued_at: int              # quoting enclave's logical clock
    signature: bytes

    def body(self) -> bytes:
        return b"|".join([b"quote-v1", self.worker_id.encode(),
                          self.measurement, self.report_data,
                          str(self.issued_at).encode()])


class QuotingKey:
    """The (software) quoting enclave's signing secret."""

    def __init__(self, secret: bytes):
        self._secret = secret

    @classmethod
    def from_seed(cls, seed: int) -> "QuotingKey":
        return cls(hashlib.sha256(f"repro-quoting-{seed}".encode()).digest())

    def _sign(self, body: bytes) -> bytes:
        return hmac.new(self._secret, body, hashlib.sha256).digest()

    def quote(self, worker_id: str, measurement: bytes,
              report_data: bytes = b"", *, now: int = 0) -> Quote:
        q = Quote(worker_id=worker_id, measurement=measurement,
                  report_data=report_data, issued_at=now, signature=b"")
        return Quote(worker_id=worker_id, measurement=measurement,
                     report_data=report_data, issued_at=now,
                     signature=self._sign(q.body()))

    def check_signature(self, q: Quote) -> bool:
        return hmac.compare_digest(self._sign(q.body()), q.signature)


@dataclass
class QuotePolicy:
    """What the verifier accepts: allowlisted measurements, a freshness
    window, and a revocation list (the live-eviction mechanism)."""

    allowed_measurements: Set[bytes] = field(default_factory=set)
    max_quote_age: Optional[int] = None   # logical-clock ticks; None = any
    revoked: Set[str] = field(default_factory=set)

    def allow(self, measurement: bytes) -> None:
        self.allowed_measurements.add(measurement)

    def is_revoked(self, worker_id: str) -> bool:
        return worker_id in self.revoked


def verify_quote(qk: QuotingKey, q: Quote, policy: QuotePolicy, *,
                 now: int = 0,
                 expect_report_data: Optional[bytes] = None) -> None:
    """Full verdict; raises :class:`QuoteError` with a stable reason tag.

    Order matters for the error surface: a forged signature is rejected
    before any policy detail leaks.
    """
    if not qk.check_signature(q):
        raise QuoteError("bad-signature", q.worker_id)
    if policy.is_revoked(q.worker_id):
        raise QuoteError("revoked", q.worker_id)
    if q.measurement not in policy.allowed_measurements:
        raise QuoteError("measurement-not-allowed",
                         f"{q.worker_id}: {q.measurement.hex()[:16]}...")
    if policy.max_quote_age is not None and \
            now - q.issued_at > policy.max_quote_age:
        raise QuoteError("stale",
                         f"{q.worker_id}: age {now - q.issued_at} > "
                         f"{policy.max_quote_age}")
    if expect_report_data is not None and \
            not hmac.compare_digest(q.report_data, expect_report_data):
        raise QuoteError("report-data-mismatch", q.worker_id)
