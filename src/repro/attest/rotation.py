"""Epoch rekeying: the HKDF ratchet over session keys.

A ChaCha20 session key must rotate before its 64-bit chunk counter wraps
(repro.crypto.keys guards the hard limit); operationally you rotate far
earlier so a leaked epoch key exposes a bounded window of traffic.  The
ratchet is one-way (HKDF-SHA256 keyed by the handshake transcript), so
epoch N+1 keys reveal nothing about epoch N — forward secrecy per epoch
without re-running the handshake.  `KeyDirectory.advance_epoch` applies
:func:`ratchet_key` to every live session and zeroes its chunk counter.
"""
from __future__ import annotations

import hashlib
import hmac

import numpy as np

from repro.crypto.keys import StageKey


def hkdf_sha256(ikm: bytes, *, salt: bytes = b"", info: bytes = b"",
                length: int = 32) -> bytes:
    """RFC 5869 extract-then-expand (hashlib/hmac only, no deps)."""
    prk = hmac.new(salt or b"\x00" * 32, ikm, hashlib.sha256).digest()
    out, block = b"", b""
    counter = 1
    while len(out) < length:
        block = hmac.new(prk, block + info + bytes([counter]),
                         hashlib.sha256).digest()
        out += block
        counter += 1
    return out[:length]


def key_from_bytes(material: bytes, stage_id: int) -> StageKey:
    """32 bytes of KDF output -> a (8,) uint32 ChaCha20 StageKey."""
    assert len(material) >= 32
    words = np.frombuffer(material[:32], dtype="<u4").copy()
    return StageKey(key=words, stage_id=stage_id)


def ratchet_key(key: StageKey, *, epoch: int,
                transcript: bytes = b"") -> StageKey:
    """One-way epoch ratchet: K_{epoch} = HKDF(K_prev, transcript, epoch).

    Binding the handshake transcript keeps two sessions that somehow
    ratcheted from equal material on distinct schedules distinct.
    """
    ikm = np.asarray(key.key, dtype="<u4").tobytes()
    material = hkdf_sha256(ikm, salt=transcript,
                           info=b"ss-epoch-%d" % epoch)
    return key_from_bytes(material, key.stage_id)
