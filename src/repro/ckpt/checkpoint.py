"""Sealed checkpoints: encrypt-then-MAC at rest, async save, elastic restore.

The paper's sealed-storage analogue (§2: "data can also be persisted on
stable storage protected by a seal key").  Checkpoints are written as one
``.npz`` of flattened leaves + a JSON manifest; in ``sealed`` mode every
leaf is ChaCha20-encrypted and the whole archive carries a host Poly1305
tag (128-bit, big-int math is fine on the host — DESIGN.md §2).

Elastic restore: leaves are loaded on host and re-placed under the
*current* mesh's shardings — a checkpoint written on 16x16 restores onto
2x16x16 (or a single CPU device) unchanged, which is what makes
checkpoint/restart the recovery and re-scaling primitive (ft/).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.crypto import poly1305_host
from repro.crypto.keys import root_key_from_seed

Params = Any


def _flatten(tree: Params) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    out = {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            # numpy can't serialize ml_dtypes (bfloat16 etc): store a u16
            # view; the dtype is recorded separately and restored on load.
            out[f"leaf_{i}__bf16"] = a.view(np.uint16)
        else:
            out[f"leaf_{i}"] = a
    return out, treedef


def _seal_key(seed: int) -> bytes:
    return hashlib.sha256(root_key_from_seed(seed) + b"|seal").digest()


def _stream_xor(key32: bytes, data: bytes) -> bytes:
    """Host-side ChaCha20-CTR via the numpy reference (vectorized)."""
    from repro.crypto import chacha20 as cc
    import jax.numpy as jnp
    key = np.frombuffer(key32, dtype="<u4")[:8]
    nonce = np.array([0x5EA1, 0, 0], dtype=np.uint32)  # "seal" domain
    n = len(data)
    pad = (-n) % 4
    words = np.frombuffer(data + b"\0" * pad, dtype="<u4").copy()
    out = np.asarray(cc.encrypt_words(jnp.asarray(key), jnp.asarray(nonce),
                                      jnp.asarray(words)))
    return out.tobytes()[:n]


def save(path: str, step: int, params: Params, opt_state: Params,
         *, sealed: bool = True, seed: int = 0,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Write checkpoint atomically; returns the final directory path."""
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f".tmp-step-{step:08d}")
    final = os.path.join(path, f"step-{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    payload, treedefs = {}, {}
    for name, tree in (("params", params), ("opt", opt_state)):
        flat, treedef = _flatten(tree)
        payload.update({f"{name}__{k}": v for k, v in flat.items()})
        treedefs[name] = str(treedef)

    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **payload)
    with open(npz_path, "rb") as f:
        blob = f.read()
    manifest = {
        "step": step,
        "sealed": sealed,
        "treedefs": treedefs,
        "extra": extra or {},
        "sha256_plain": hashlib.sha256(blob).hexdigest(),
        "time": time.time(),
    }
    if sealed:
        key = _seal_key(seed)
        blob = _stream_xor(key, blob)
        manifest["poly1305"] = poly1305_host.poly1305(key, blob).hex()
        with open(os.path.join(tmp, "arrays.sealed"), "wb") as f:
            f.write(blob)
        os.remove(npz_path)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(path: str, step: int, params: Params, opt_state: Params,
               **kw) -> threading.Thread:
    """Non-blocking save: device->host copy happens before returning (so
    training can mutate donated buffers), disk write in a daemon thread."""
    params_h = jax.tree.map(np.asarray, params)
    opt_h = jax.tree.map(np.asarray, opt_state)
    t = threading.Thread(target=save, args=(path, step, params_h, opt_h),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(path)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore(path: str, step: Optional[int] = None, *, seed: int = 0,
            params_like: Params = None, opt_like: Params = None,
            shardings: Optional[Tuple[Params, Params]] = None):
    """Load a checkpoint; verifies the seal. Returns (step, params, opt).

    params_like/opt_like provide the pytree structure (from templates);
    shardings (optional) re-place leaves onto the current mesh (elastic
    restore across different mesh shapes).
    """
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["sealed"]:
        key = _seal_key(seed)
        with open(os.path.join(d, "arrays.sealed"), "rb") as f:
            blob = f.read()
        tag = bytes.fromhex(manifest["poly1305"])
        if not poly1305_host.poly1305_verify(key, blob, tag):
            raise ValueError(f"checkpoint {d}: Poly1305 verification FAILED "
                             "(tampered or wrong seal key)")
        blob = _stream_xor(key, blob)
        if hashlib.sha256(blob).hexdigest() != manifest["sha256_plain"]:
            raise ValueError(f"checkpoint {d}: plaintext hash mismatch")
        import io
        arrays = np.load(io.BytesIO(blob))
    else:
        arrays = np.load(os.path.join(d, "arrays.npz"))

    def rebuild(name, like, shard):
        import ml_dtypes
        n = len(jax.tree.leaves(like))
        leaves = []
        for i in range(n):
            k = f"{name}__leaf_{i}"
            if k in arrays:
                leaves.append(arrays[k])
            else:
                leaves.append(arrays[f"{k}__bf16"].view(ml_dtypes.bfloat16))
        treedef = jax.tree.structure(like)
        if shard is not None:
            sleaves = jax.tree.leaves(shard)
            leaves = [jax.device_put(x, s) for x, s in zip(leaves, sleaves)]
        return jax.tree.unflatten(treedef, leaves)

    p_sh, o_sh = shardings if shardings else (None, None)
    params = rebuild("params", params_like, p_sh)
    opt = rebuild("opt", opt_like, o_sh)
    return step, params, opt
