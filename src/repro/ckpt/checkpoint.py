"""Sealed checkpoints: encrypt-then-MAC at rest, async save, elastic restore.

The paper's sealed-storage analogue (§2: "data can also be persisted on
stable storage protected by a seal key").  Checkpoints are written as one
``.npz`` of flattened leaves + a JSON manifest; in ``sealed`` mode the
archive blob rides the batched AEAD fast path
(:func:`repro.crypto.aead.seal_many`): it is chunked into fixed-width
uint32 rows and every row is ChaCha20-encrypted + CW-MAC-tagged in ONE
compiled program, under a per-checkpoint key (seed key x random salt)
with the step mixed into each row's nonce counter — no (key, nonce) pair
recurs across checkpoints or stores.  ``restore`` verifies a keyed MAC
over the whole tag list + length (truncation-proof) and then every row's
MAC verdict, raising on tamper — a flipped ciphertext bit or a dropped
trailing row can no longer silently corrupt a restored leaf.

Elastic restore: leaves are loaded on host and re-placed under the
*current* mesh's shardings — a checkpoint written on 16x16 restores onto
2x16x16 (or a single CPU device) unchanged, which is what makes
checkpoint/restart the recovery and re-scaling primitive (ft/).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.crypto import aead
from repro.crypto.keys import root_key_from_seed

Params = Any

# Blob rows for the batched seal: 16 KiB of words each keeps B reasonable
# for multi-MB checkpoints while tiny test states stay a 1-row batch.
_ROW_WORDS = 4096
_SEAL_DOMAIN = np.uint32(0x5EA1)      # nonce word 0: "seal" domain
_ROWS_PER_STEP = 1 << 20              # counter = step * 2^20 + row


def _flatten(tree: Params) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    out = {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            # numpy can't serialize ml_dtypes (bfloat16 etc): store a u16
            # view; the dtype is recorded separately and restored on load.
            out[f"leaf_{i}__bf16"] = a.view(np.uint16)
        else:
            out[f"leaf_{i}"] = a
    return out, treedef


def _seal_key(seed: int) -> bytes:
    return hashlib.sha256(root_key_from_seed(seed) + b"|seal").digest()


def _blob_rows(data: bytes) -> Tuple[np.ndarray, int]:
    """bytes -> (B, _ROW_WORDS) u32 rows (zero-padded) + original length."""
    n = len(data)
    row_bytes = _ROW_WORDS * 4
    pad = (-n) % row_bytes
    words = np.frombuffer(data + b"\0" * pad, dtype="<u4")
    return words.reshape(-1, _ROW_WORDS).copy(), n


def _row_nonces(n_rows: int, step: int) -> np.ndarray:
    """Per-row nonces: (0x5EA1 domain, step * 2^20 + row) — unique per
    (seal key, checkpoint step, row), so re-sealing a later step under
    the same seal key never reuses a keystream."""
    if n_rows > _ROWS_PER_STEP:
        raise ValueError(f"checkpoint too large: {n_rows} rows > "
                         f"{_ROWS_PER_STEP} per step")
    c = np.uint64(step) * np.uint64(_ROWS_PER_STEP) + \
        np.arange(n_rows, dtype=np.uint64)
    return np.stack([np.full(n_rows, _SEAL_DOMAIN),
                     (c & np.uint64(0xFFFFFFFF)).astype(np.uint32),
                     (c >> np.uint64(32)).astype(np.uint32)],
                    axis=-1).astype(np.uint32)


def _store_key(key32: bytes, salt: bytes) -> bytes:
    """Per-checkpoint seal key: the seed key mixed with a random salt, so
    two stores sealed under the same seed (and step) never share a
    ChaCha20 keystream."""
    return hashlib.sha256(key32 + b"|store|" + salt).digest()


def _tags_mac(key32: bytes, step: int, tags: bytes, n_bytes: int) -> str:
    """Keyed MAC binding the row-tag list, row count, and plaintext
    length — per-row CW-MACs alone would let an attacker truncate
    trailing rows (drop rows + their tags, shrink n_bytes) undetected."""
    import hmac
    body = b"ckpt-tags|%d|%d|" % (step, n_bytes) + tags
    return hmac.new(key32, body, hashlib.sha256).hexdigest()


def _seal_blob(key32: bytes, step: int, data: bytes
               ) -> Tuple[bytes, Dict[str, Any]]:
    """AEAD-seal a blob via the batched fast path.

    Returns (ciphertext bytes incl. row padding, manifest metadata:
    row tags + salt + length + the tag-list MAC).
    """
    salt = os.urandom(16)
    key32 = _store_key(key32, salt)
    key = np.frombuffer(key32, dtype="<u4")[:8].copy()
    rows, n = _blob_rows(data)
    ct, tags = aead.seal_many(key, _row_nonces(rows.shape[0], step), rows)
    tags_b = np.asarray(tags).astype("<u4").tobytes()
    meta = {"tags": tags_b.hex(), "n_bytes": n, "salt": salt.hex(),
            "row_words": _ROW_WORDS, "nonce_step": step,
            "mac": _tags_mac(key32, step, tags_b, n)}
    return np.asarray(ct).astype("<u4").tobytes(), meta


def _open_blob(key32: bytes, a: Dict[str, Any], blob: bytes,
               what: str) -> bytes:
    """Open + verify a sealed blob; raises ValueError on any tamper."""
    import hmac
    step, n_bytes = a["nonce_step"], a["n_bytes"]
    key32 = _store_key(key32, bytes.fromhex(a["salt"]))
    tags_b = bytes.fromhex(a["tags"])
    if not hmac.compare_digest(a["mac"],
                               _tags_mac(key32, step, tags_b, n_bytes)):
        raise ValueError(
            f"checkpoint {what}: AEAD verification FAILED on the tag list "
            f"(rows dropped/reordered, length changed, or wrong seal key)")
    key = np.frombuffer(key32, dtype="<u4")[:8].copy()
    if len(blob) % (_ROW_WORDS * 4):
        raise ValueError(f"checkpoint {what}: sealed blob length "
                         f"{len(blob)} is not row-aligned (truncated?)")
    ct = np.frombuffer(blob, dtype="<u4").reshape(-1, _ROW_WORDS)
    tags = np.frombuffer(tags_b, dtype="<u4").reshape(-1, 2)
    if tags.shape[0] != ct.shape[0]:
        raise ValueError(f"checkpoint {what}: {tags.shape[0]} tags for "
                         f"{ct.shape[0]} rows")
    pt, ok = aead.open_many(key, _row_nonces(ct.shape[0], step), ct, tags)
    ok = np.asarray(ok)
    if not ok.all():
        bad = np.flatnonzero(~ok).tolist()
        raise ValueError(
            f"checkpoint {what}: AEAD verification FAILED on rows {bad} "
            f"(tampered or wrong seal key)")
    return np.asarray(pt).astype("<u4").tobytes()[:n_bytes]


def save(path: str, step: int, params: Params, opt_state: Params,
         *, sealed: bool = True, seed: int = 0,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Write checkpoint atomically; returns the final directory path."""
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f".tmp-step-{step:08d}")
    final = os.path.join(path, f"step-{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    payload, treedefs = {}, {}
    for name, tree in (("params", params), ("opt", opt_state)):
        flat, treedef = _flatten(tree)
        payload.update({f"{name}__{k}": v for k, v in flat.items()})
        treedefs[name] = str(treedef)

    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **payload)
    with open(npz_path, "rb") as f:
        blob = f.read()
    manifest = {
        "step": step,
        "sealed": sealed,
        "treedefs": treedefs,
        "extra": extra or {},
        "sha256_plain": hashlib.sha256(blob).hexdigest(),
        "time": time.time(),
    }
    if sealed:
        key = _seal_key(seed)
        blob, aead_meta = _seal_blob(key, step, blob)
        manifest["aead"] = aead_meta
        with open(os.path.join(tmp, "arrays.sealed"), "wb") as f:
            f.write(blob)
        os.remove(npz_path)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(path: str, step: int, params: Params, opt_state: Params,
               **kw) -> threading.Thread:
    """Non-blocking save: device->host copy happens before returning (so
    training can mutate donated buffers), disk write in a daemon thread."""
    params_h = jax.tree.map(np.asarray, params)
    opt_h = jax.tree.map(np.asarray, opt_state)
    t = threading.Thread(target=save, args=(path, step, params_h, opt_h),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(path)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore(path: str, step: Optional[int] = None, *, seed: int = 0,
            params_like: Params = None, opt_like: Params = None,
            shardings: Optional[Tuple[Params, Params]] = None):
    """Load a checkpoint; verifies the seal. Returns (step, params, opt).

    params_like/opt_like provide the pytree structure (from templates);
    shardings (optional) re-place leaves onto the current mesh (elastic
    restore across different mesh shapes).
    """
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["sealed"]:
        key = _seal_key(seed)
        with open(os.path.join(d, "arrays.sealed"), "rb") as f:
            blob = f.read()
        a = manifest.get("aead")
        if a is None:
            raise ValueError(
                f"checkpoint {d}: sealed with a pre-AEAD format "
                f"(manifest has {'poly1305' if 'poly1305' in manifest else 'no'}"
                f" seal metadata) — re-save it with the current code")
        if a.get("row_words", _ROW_WORDS) != _ROW_WORDS:
            raise ValueError(f"checkpoint {d}: unsupported row_words "
                             f"{a['row_words']}")
        blob = _open_blob(key, a, blob, d)
        if hashlib.sha256(blob).hexdigest() != manifest["sha256_plain"]:
            raise ValueError(f"checkpoint {d}: plaintext hash mismatch")
        import io
        arrays = np.load(io.BytesIO(blob))
    else:
        arrays = np.load(os.path.join(d, "arrays.npz"))

    def rebuild(name, like, shard):
        import ml_dtypes
        n = len(jax.tree.leaves(like))
        leaves = []
        for i in range(n):
            k = f"{name}__leaf_{i}"
            if k in arrays:
                leaves.append(arrays[k])
            else:
                leaves.append(arrays[f"{k}__bf16"].view(ml_dtypes.bfloat16))
        treedef = jax.tree.structure(like)
        if shard is not None:
            sleaves = jax.tree.leaves(shard)
            leaves = [jax.device_put(x, s) for x, s in zip(leaves, sleaves)]
        return jax.tree.unflatten(treedef, leaves)

    p_sh, o_sh = shardings if shardings else (None, None)
    params = rebuild("params", params_like, p_sh)
    opt = rebuild("opt", opt_like, o_sh)
    return step, params, opt
