"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture lives in its own module exposing ``ARCH_ID``,
``MODEL`` (a :class:`~repro.configs.base.ModelConfig`) and ``OPTIMIZER``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (  # noqa: F401  (re-exported)
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    RunConfig,
    SecureStreamConfig,
    ShapeConfig,
    ShardingConfig,
    SHAPES,
    SSMConfig,
    XLSTMConfig,
    reduce_for_smoke,
)

_ARCH_MODULES: Dict[str, str] = {
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "qwen2.5-32b": "repro.configs.qwen2p5_32b",
    "granite-34b": "repro.configs.granite_34b",
    "llama3.2-1b": "repro.configs.llama3p2_1b",
    "qwen2.5-14b": "repro.configs.qwen2p5_14b",
    "musicgen-large": "repro.configs.musicgen_large",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_model_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).MODEL


def get_optimizer_config(arch_id: str) -> OptimizerConfig:
    return importlib.import_module(_ARCH_MODULES[arch_id]).OPTIMIZER


def get_run_config(arch_id: str, shape: str, **overrides) -> RunConfig:
    model = get_model_config(arch_id)
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    kw = dict(
        model=model,
        shape=SHAPES[shape],
        optimizer=get_optimizer_config(arch_id),
    )
    if hasattr(mod, "SHARDING"):
        kw["sharding"] = mod.SHARDING
    kw.update(overrides)
    return RunConfig(**kw)


def all_cells() -> List[Tuple[str, str]]:
    """The full assignment grid: 10 archs x 4 shapes = 40 cells."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]


def cell_supported(arch_id: str, shape: str) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; reason if not.

    Per the assignment: long_500k needs sub-quadratic attention — skipped
    (and recorded) for pure full-attention archs.
    """
    m = get_model_config(arch_id)
    if shape == "long_500k" and not m.sub_quadratic:
        return False, ("full-attention arch: 500k-token decode is the "
                       "quadratic regime this shape excludes (DESIGN.md §4)")
    return True, ""
