"""Configuration dataclasses for the repro framework.

Every assigned architecture is described by a :class:`ModelConfig`; the
training / serving geometry by :class:`RunConfig`; the secure-stream data
path by :class:`SecureStreamConfig`.  Configs are plain frozen dataclasses so
they hash, compare, and serialize trivially (the launcher dumps them next to
checkpoints for elastic restore).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model-family sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (expert-parallel over `model`)."""

    num_experts: int
    top_k: int
    # Per-expert hidden width (the assignment tables give d_ff per expert).
    d_expert: int
    # Fixed-capacity routing: capacity per *expert shard* is
    #   ceil(tokens * top_k / num_expert_shards) * capacity_factor.
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Load-balancing auxiliary loss weight (Switch-style).
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style state-space block configuration."""

    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    headdim: int = 64
    chunk_size: int = 256  # chunkwise-parallel scan block


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack configuration (alternating mLSTM / sLSTM)."""

    # Indices (mod pattern length) that are sLSTM; remainder are mLSTM.
    slstm_every: int = 2          # every 2nd block is sLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    chunk_size: int = 256         # chunkwise-parallel mLSTM scan block


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                     # dense FFN width (0 for pure-SSM families)
    vocab_size: int

    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_type: str = "swiglu"      # swiglu (3 mats) | gelu (2 mats)
    rope_theta: float = 500_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # hybrid: attention layer period (Zamba-style shared attention block).
    attn_every: int = 0           # 0 -> attention in every layer (or none for ssm)
    shared_attention: bool = False

    # Modality frontend stub: "none" | "vision_patches" | "audio_frames".
    frontend: str = "none"
    frontend_dim: int = 0         # embedding dim of precomputed patch/frame inputs

    # Whether attention is full quadratic (drives the long_500k skip rule).
    attention_free: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities ------------------------------------------------

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run the 500k-token long-context decode."""
        return self.family in ("ssm", "hybrid") or self.attention_free

    def param_count(self) -> int:
        """Exact parameter count, summed from the model's param template
        (single source of truth — used for the 6·N·D roofline numerators)."""
        import math
        from repro.models.api import param_template   # lazy: no import cycle
        from repro.models.layers import is_spec
        import jax
        leaves = jax.tree.leaves(param_template(self), is_leaf=is_spec)
        return sum(math.prod(s.shape) for s in leaves)

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts only top_k experts)."""
        full = self.param_count()
        if self.moe is None:
            return full
        expert_p = 3 * self.d_model * self.moe.d_expert
        dead = self.num_layers * (self.moe.num_experts - self.moe.top_k) \
            * expert_p
        return full - dead


# ---------------------------------------------------------------------------
# Run geometry (shapes from the assignment grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"           # adamw | adafactor | sgdm
    lr: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # ZeRO-style sharding of optimizer state over the data(+pod) axes.
    zero_sharding: bool = True
    # Gradient all-reduce compression: "none" | "fp16" | "int8".
    grad_compression: str = "none"


@dataclass(frozen=True)
class ShardingConfig:
    """Logical-axis -> mesh-axis rules (MaxText-style)."""

    # Each logical axis maps to a tuple of mesh axes tried in order; the
    # partitioner shards on the first whose size divides the dim (GSPMD
    # padding is allowed as a fallback when `allow_uneven`).
    rules: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("batch", ("pod", "data")),
        ("seq", ()),               # sequence sharding enabled per-shape
        ("seq_res", ()),           # SP residual stream (enable per-arch)
        ("moe_ff", ()),            # FSDP storage of expert weights
        ("embed", ()),             # activation d_model: replicated
        ("vocab", ("model",)),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("mlp", ("model",)),
        ("experts", ("model",)),
        ("kv_seq", ()),            # decode KV cache sequence dim
        ("zero", ("data",)),       # optimizer-state sharding axis
    )
    allow_uneven: bool = True

    def with_rule(self, name: str, axes: Tuple[str, ...]) -> "ShardingConfig":
        rules = tuple((k, axes if k == name else v) for k, v in self.rules)
        return dataclasses.replace(self, rules=rules)

    def lookup(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self.rules)


@dataclass(frozen=True)
class SecureStreamConfig:
    """The paper's technique, as data-path configuration."""

    # Security mode, mirroring the paper's three Fig-6 configurations:
    #   "plain"      -- cleartext end to end (baseline, unsafe)
    #   "encrypted"  -- AEAD-sealed at rest / on the wire, decrypted *outside*
    #                   the enclave kernels (trusts the operator)
    #   "enclave"    -- sealed everywhere; plaintext exists only inside the
    #                   fused Pallas enclave kernels (VMEM)
    mode: str = "enclave"
    chunk_bytes: int = 65_536      # paper Fig 4 knee: 64 KB
    mac: str = "cwmac"             # cwmac | none (poly1305 reserved for host)
    seal_checkpoints: bool = True
    seal_pp_boundaries: bool = True


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = OptimizerConfig()
    sharding: ShardingConfig = ShardingConfig()
    secure: SecureStreamConfig = SecureStreamConfig()
    remat: str = "full"            # none | full | selective
    microbatches: int = 1          # grad-accumulation microbatches
    seed: int = 0
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Reduced ("smoke") configs: same family, tiny dims, run on 1 CPU device.
# ---------------------------------------------------------------------------


def reduce_for_smoke(m: ModelConfig) -> ModelConfig:
    """Shrink a full config to a CPU-runnable config of the same family."""
    kw: Dict[str, Any] = dict(
        arch_id=m.arch_id + "-smoke",
        family=m.family,
        num_layers=min(m.num_layers, 2 if m.family != "hybrid" else 7),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(m.num_kv_heads, 4) if m.num_kv_heads > 1 else 1,
        d_ff=128 if m.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        qkv_bias=m.qkv_bias,
        mlp_type=m.mlp_type,
        tie_embeddings=m.tie_embeddings,
        attn_every=m.attn_every,
        shared_attention=m.shared_attention,
        frontend=m.frontend,
        frontend_dim=32 if m.frontend != "none" else 0,
        attention_free=m.attention_free,
    )
    if m.moe is not None:
        kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                              capacity_factor=m.moe.capacity_factor)
    if m.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=16, conv_width=4, expand=2, headdim=16,
                              chunk_size=16)
    if m.xlstm is not None:
        kw["xlstm"] = XLSTMConfig(slstm_every=m.xlstm.slstm_every, chunk_size=16)
    return ModelConfig(**kw)
