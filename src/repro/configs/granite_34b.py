"""Granite-34B-Code (arXiv:2405.04324) — llama-arch, MQA.

88L d_model=6144 48H (kv=1, multi-query) d_ff=24576 vocab=49152.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig,
                                ShardingConfig)

ARCH_ID = "granite-34b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    head_dim=128,
    mlp_type="gelu",  # gpt-bigcode 2-matrix GELU MLP (=> ~34B, not 47B)
    rope_theta=10_000.0,
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)

# Sequence-parallel residual stream: shards the per-layer remat
# stash over the model axis (see EXPERIMENTS.md §Perf).
SHARDING = ShardingConfig().with_rule("seq_res", ("model",))
