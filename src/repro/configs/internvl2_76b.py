"""InternVL2-76B (arXiv:2404.16821) — InternViT + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  Per the assignment
the ViT frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings which a linear projector maps into the LM residual stream.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig,
                                ShardingConfig)

ARCH_ID = "internvl2-76b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    frontend="vision_patches",
    frontend_dim=3200,  # InternViT-6B output width
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)

# Sequence-parallel residual stream: shards the per-layer remat
# stash over the model axis (see EXPERIMENTS.md §Perf).
SHARDING = ShardingConfig().with_rule("seq_res", ("model",))
