"""Kimi K2 — trillion-parameter MoE (arXiv:2501.kimi2, paper-table).

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840, MoE 384e top-8.
"""
from repro.configs.base import (ModelConfig, MoEConfig, OptimizerConfig,
                                ShardingConfig)

ARCH_ID = "kimi-k2-1t-a32b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=163_840,
    head_dim=112,
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048),
    rope_theta=50_000.0,
)

# 1T-param training is HBM-gated: use a factored, stateless-momentum
# optimizer with ZeRO sharding (see DESIGN.md §5 and EXPERIMENTS §Dry-run).
OPTIMIZER = OptimizerConfig(name="adafactor", zero_sharding=True)

# Expert weights FSDP-sharded over the data axis (129 GB -> 8 GB per chip,
# re-gathered per layer); residual stream sequence-parallel over the model
# axis (remat stash 57 GB -> 3.6 GB per chip).
SHARDING = (ShardingConfig()
            .with_rule("moe_ff", ("data",))
            .with_rule("seq_res", ("model",)))
