"""Llama-3.2-1B (hf:meta-llama/Llama-3.2-1B) — small dense llama3.

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig,
                                ShardingConfig)

ARCH_ID = "llama3.2-1b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=500_000.0,
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)

# A 1.2B model does not want TP=16: Megatron activation all-reduces dominate
# (baseline: t_coll/t_compute = 10x, EXPERIMENTS.md §Perf llama iteration 1).
# Right-size: pure data parallelism over ALL mesh axes (batch 256 = 16x16),
# ZeRO optimizer states sharded over both axes.
SHARDING = (ShardingConfig()
            .with_rule("batch", ("pod", "data", "model"))
            .with_rule("heads", ())
            .with_rule("kv_heads", ())
            .with_rule("mlp", ())
            .with_rule("vocab", ())
            .with_rule("zero", ("data", "model")))
