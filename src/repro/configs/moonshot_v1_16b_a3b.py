"""Moonshot/Moonlight 16B-A3B MoE (hf:moonshotai/Moonlight-16B-A3B).

48L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import (ModelConfig, MoEConfig,
                                OptimizerConfig, ShardingConfig)

ARCH_ID = "moonshot-v1-16b-a3b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=163_840,
    head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)

# Sequence-parallel residual stream: shards the per-layer remat
# stash over the model axis (see EXPERIMENTS.md §Perf).
SHARDING = ShardingConfig().with_rule("seq_res", ("model",))
