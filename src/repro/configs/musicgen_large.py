"""MusicGen-Large (arXiv:2306.05284) — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.  Per the assignment the
EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings alongside codebook token ids.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig,
                                ShardingConfig)

ARCH_ID = "musicgen-large"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    frontend="audio_frames",
    frontend_dim=128,  # EnCodec latent frame width
    rope_theta=10_000.0,
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)

# Sequence-parallel residual stream: shards the per-layer remat
# stash over the model axis (see EXPERIMENTS.md §Perf).
SHARDING = ShardingConfig().with_rule("seq_res", ("model",))
