"""Qwen2.5-14B (hf:Qwen family) — dense, GQA, QKV bias.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig,
                                ShardingConfig)

ARCH_ID = "qwen2.5-14b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13_824,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)

# Sequence-parallel residual stream: shards the per-layer remat
# stash over the model axis (see EXPERIMENTS.md §Perf).
SHARDING = ShardingConfig().with_rule("seq_res", ("model",))
