"""The paper's own benchmark application: the DelayedFlights pipeline.

Computes, per air carrier, the average delay and the count of delayed
flights over a flight-record stream (paper §5.2, Table 1), as a
map -> filter -> reduce SecureStreams pipeline under one of the three
security modes of Fig. 6.
"""
from dataclasses import dataclass

from repro.configs.base import SecureStreamConfig

ARCH_ID = "securestreams-flightdelay"


@dataclass(frozen=True)
class FlightPipelineConfig:
    num_carriers: int = 20          # paper: 20 air carriers
    num_records: int = 1_000_000    # scaled-down from the paper's 28M (CPU)
    record_words: int = 8           # uint32 words per record
    workers_per_stage: int = 1      # paper scales 1 / 2 / 4
    chunk_records: int = 2_048      # records per stream chunk
    secure: SecureStreamConfig = SecureStreamConfig(mode="enclave")


CONFIG = FlightPipelineConfig()
