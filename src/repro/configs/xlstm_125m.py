"""xLSTM-125M (arXiv:2405.04517) — alternating sLSTM + mLSTM blocks.

12L d_model=768 4H d_ff=0 vocab=50304.  Attention-free (recurrent) =>
sub-quadratic; runs the long_500k shape.
"""
from repro.configs.base import ModelConfig, OptimizerConfig, XLSTMConfig

ARCH_ID = "xlstm-125m"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=192,
    xlstm=XLSTMConfig(slstm_every=2, chunk_size=256),
    attention_free=True,
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)
