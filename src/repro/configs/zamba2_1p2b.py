"""Zamba2-1.2B (arXiv:2411.15242) — Mamba2 backbone + shared attention block.

38L d_model=2048 32H (kv=32) d_ff=8192 ssm_state=64 vocab=32000.  A single
weight-shared attention+FFN block is invoked every 6th layer (Zamba's trick);
all other layers are Mamba2.  Hybrid => sub-quadratic; runs long_500k.
"""
from repro.configs.base import ModelConfig, OptimizerConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"

MODEL = ModelConfig(
    arch_id=ARCH_ID,
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    head_dim=64,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, headdim=64),
    attn_every=6,
    shared_attention=True,
)

OPTIMIZER = OptimizerConfig(name="adamw", zero_sharding=True)
