from repro.core.observable import Observable  # noqa: F401
from repro.core.pipeline import Pipeline, Stage  # noqa: F401
from repro.core.enclave import EnclaveExecutor, SealedChunk, \
    SealedWindow  # noqa: F401
