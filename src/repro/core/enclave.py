"""Enclave executor: runs operators under one of the paper's three modes.

Fig. 6 of the paper compares three deployments; they map here to:

* ``plain``     — operator on cleartext chunks (baseline, unsafe);
* ``encrypted`` — AEAD decrypt -> operator -> AEAD encrypt as *separate* XLA
  ops: ciphertext on the wire/at rest, but plaintext transits HBM during
  compute (paper: "encrypted data but skip the enclaves" — trusts the
  operator);
* ``enclave``   — the fused Pallas kernel (repro.kernels.enclave_map):
  plaintext exists only in VMEM inside the kernel, HBM sees ciphertext
  end-to-end.  Operators must come from the static registry (the paper's
  no-dynamic-linking constraint, §4).

Integrity: every chunk carries a CW-MAC tag; ``open`` failures surface as
dropped chunks + an error count (reactive ``on_error``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import aead, chacha20, cwmac
from repro.crypto.keys import StageKey, current_epoch as _cur_epoch, \
    resolve_key as _key_at
from repro.kernels.enclave_map import ops as enclave_ops

U32 = jnp.uint32


@dataclass
class SealedChunk:
    """Fixed-shape ciphertext unit flowing between stages."""
    blocks: jax.Array             # (N, 16) u32 ciphertext (or plaintext words
                                  # in plain mode)
    tag: Optional[jax.Array]      # (2,) u32 CW-MAC or None
    counter: int                  # per-stream chunk counter -> nonce
    meta: Tuple                   # tensor framing (shape, dtype, pad)
    n_words: int                  # valid words before block padding
    epoch: int = 0                # key epoch assigned at ingress; every
                                  # edge seals this chunk under ITS epoch
                                  # (counters are epoch-local — resealing
                                  # under a later epoch would reuse that
                                  # epoch's (key, nonce) pairs)


def _words_to_blocks(words: jax.Array) -> Tuple[jax.Array, int]:
    n = words.shape[0]
    n_blocks = (n + 15) // 16
    padded = jnp.pad(words, (0, n_blocks * 16 - n))
    return padded.reshape(n_blocks, 16), n


def seal_tensor(key, counter: int, x: jax.Array,
                epoch: Optional[int] = None) -> SealedChunk:
    """Seal under ``key`` at ``epoch`` (the handle's current epoch when
    None — ingress; executors pass the chunk's own epoch through)."""
    if epoch is None:
        epoch = _cur_epoch(key)
    k = _key_at(key, epoch)
    words, meta = aead.tensor_to_words(x)
    nonce = jnp.asarray(k.nonce(counter))
    ct, tag = aead.seal(jnp.asarray(k.key), nonce, words)
    blocks, n = _words_to_blocks(ct)
    return SealedChunk(blocks=blocks, tag=tag, counter=counter, meta=meta,
                       n_words=n, epoch=epoch)


def open_tensor(key, chunk: SealedChunk) -> Tuple[jax.Array, jax.Array]:
    k = _key_at(key, chunk.epoch)
    nonce = jnp.asarray(k.nonce(chunk.counter))
    ct = chunk.blocks.reshape(-1)[:chunk.n_words]
    pt, ok = aead.open_(jnp.asarray(k.key), nonce, ct, chunk.tag)
    return aead.words_to_tensor(pt, chunk.meta), ok


def plain_chunk(counter: int, x: jax.Array) -> SealedChunk:
    words, meta = aead.tensor_to_words(x)
    blocks, n = _words_to_blocks(words)
    return SealedChunk(blocks=blocks, tag=None, counter=counter, meta=meta,
                       n_words=n)


def unplain_chunk(chunk: SealedChunk) -> jax.Array:
    return aead.words_to_tensor(chunk.blocks.reshape(-1)[:chunk.n_words],
                                chunk.meta)


class EnclaveExecutor:
    """Executes one stage's operator under the configured security mode.

    ``key_in``/``key_out`` are either static :class:`StageKey`s or
    KeyDirectory edge handles (repro.attest.directory.EdgeHandle): with
    handles the executor opens AND re-seals each chunk under the epoch
    the chunk was ingressed in (chunk counters are epoch-local — mixing
    a counter into a later epoch would reuse that epoch's (key, nonce)
    pairs).  A mid-stream rekey therefore drains old-epoch chunks to the
    sink under their own ratchet lineage while newly ingressed chunks
    ride the new keys.
    """

    def __init__(self, mode: str, key_in, key_out,
                 block_rows: int = 512):
        assert mode in ("plain", "encrypted", "enclave"), mode
        self.mode = mode
        self.key_in = key_in
        self.key_out = key_out
        self.block_rows = block_rows
        self.errors = 0

    # -- generic python/jnp operator (plain + encrypted modes) --------------

    def run(self, fn: Callable[[jax.Array], jax.Array],
            chunk: SealedChunk) -> Optional[SealedChunk]:
        if self.mode == "plain":
            x = unplain_chunk(chunk)
            return plain_chunk(chunk.counter, fn(x))
        if self.mode == "encrypted":
            x, ok = open_tensor(self.key_in, chunk)
            if not bool(ok):
                self.errors += 1
                return None
            # reseal under the CHUNK's epoch (not the directory's current
            # one): counters are epoch-local, so sealing an old-epoch chunk
            # under a newer key would reuse that epoch's (key, nonce) pairs
            return seal_tensor(self.key_out, chunk.counter, fn(x),
                               epoch=chunk.epoch)
        raise ValueError(
            "enclave mode only executes registered static operators "
            "(run_static); arbitrary closures cannot be attested — "
            "the paper's no-dynamic-linking rule.")

    # -- static registered operator (all modes; enclave mode fused) ---------

    def run_static(self, op: str, const: float,
                   chunk: SealedChunk) -> Optional[SealedChunk]:
        if self.mode in ("plain", "encrypted"):
            fn = lambda x: _apply_static_f32(op, const, x)
            return self.run(fn, chunk)
        # enclave: fused decrypt->op->encrypt, VMEM-confined plaintext.
        # In and out keys both resolve at the chunk's epoch — see run().
        kin = _key_at(self.key_in, chunk.epoch)
        kout = _key_at(self.key_out, chunk.epoch)
        nonce = jnp.asarray(kin.nonce(chunk.counter))
        pad_rows = (-chunk.blocks.shape[0]) % self.block_rows
        blocks = jnp.pad(chunk.blocks, ((0, pad_rows), (0, 0)))
        # MAC check on ciphertext happens outside the enclave (it is public
        # data); the keystream offset for payload is counter0=1.
        r1, s1, r2, s2 = aead.derive_mac_keys(jnp.asarray(kin.key), nonce)
        ct_words = chunk.blocks.reshape(-1)[:chunk.n_words]
        ok = jnp.all(cwmac.mac2(ct_words, r1, s1, r2, s2) == chunk.tag)
        if not bool(ok):
            self.errors += 1
            return None
        out_blocks = enclave_ops.enclave_map(
            jnp.asarray(kin.key), jnp.asarray(kout.key),
            nonce, 1, blocks, op=op, const=const,
            block_rows=self.block_rows)[:chunk.blocks.shape[0]]
        # re-tag under the outbound key
        nonce_out = jnp.asarray(kout.nonce(chunk.counter))
        ro1, so1, ro2, so2 = aead.derive_mac_keys(
            jnp.asarray(kout.key), nonce_out)
        out_words = out_blocks.reshape(-1)[:chunk.n_words]
        tag = cwmac.mac2(out_words, ro1, so1, ro2, so2)
        return SealedChunk(blocks=out_blocks, tag=tag, counter=chunk.counter,
                           meta=chunk.meta, n_words=chunk.n_words,
                           epoch=chunk.epoch)


def _apply_static_f32(op: str, const: float, x: jax.Array) -> jax.Array:
    """jnp mirror of the kernel's static op registry (on decoded tensors)."""
    words, meta = aead.tensor_to_words(x)
    blocks, n = _words_to_blocks(words)
    out = enclave_ops.OPS[op](blocks, const)
    return aead.words_to_tensor(out.reshape(-1)[:n], meta)


def ingress(mode: str, key: StageKey, counter: int,
            x: jax.Array) -> SealedChunk:
    """Bring a source tensor into the pipeline under the security mode."""
    if mode == "plain":
        return plain_chunk(counter, x)
    return seal_tensor(key, counter, x)


def egress(mode: str, key: StageKey, chunk: SealedChunk):
    """Take a result out of the pipeline (trusted subscriber)."""
    if mode == "plain":
        return unplain_chunk(chunk), jnp.bool_(True)
    return open_tensor(key, chunk)
