"""Enclave executor: runs operators under one of the paper's three modes.

Fig. 6 of the paper compares three deployments; they map here to:

* ``plain``     — operator on cleartext chunks (baseline, unsafe);
* ``encrypted`` — AEAD decrypt -> operator -> AEAD encrypt as *separate* XLA
  ops: ciphertext on the wire/at rest, but plaintext transits HBM during
  compute (paper: "encrypted data but skip the enclaves" — trusts the
  operator);
* ``enclave``   — the fused Pallas kernel (repro.kernels.enclave_map):
  plaintext exists only in VMEM inside the kernel, HBM sees ciphertext
  end-to-end.  Operators must come from the static registry (the paper's
  no-dynamic-linking constraint, §4).

Integrity: every chunk carries a CW-MAC tag; ``open`` failures surface as
dropped chunks + an error count (reactive ``on_error``).

Window batching: the streaming engine's unit of device work is a window
of chunks, not a chunk.  :meth:`EnclaveExecutor.run_many` /
:meth:`EnclaveExecutor.run_static_many` open a whole window with
``aead.open_many``, apply the stage operator ONCE across the batch, and
re-seal with ``aead.seal_many`` (enclave mode rides the batched
``enclave_map_rows`` grid kernel, so plaintext stays VMEM-confined per
row).  MAC verdicts are **deferred**: the batched entry points return a
per-row device verdict vector without a host sync — the pipeline syncs
once per window and drops failed rows there.  Mixed-epoch windows (a
window straddling a ``rekey_every_n`` flip) resolve per-row keys, so
rows never cross keystreams.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import aead, chacha20, cwmac
from repro.crypto.keys import StageKey, current_epoch as _cur_epoch, \
    resolve_key as _key_at
from repro.kernels.enclave_map import ops as enclave_ops
from repro.obs.metrics import REGISTRY as _METRICS
from repro.obs.trace import NULL_TRACER

# the scalar enclave path launches cwmac.mac2 eagerly (ciphertext MACs
# happen OUTSIDE the fused kernel); those launches are counted here at
# the call sites — cwmac.mac2 itself also runs traced inside sealed
# programs, where a counter would only fire at trace time
_DISPATCHES = _METRICS.counter("device.dispatches")
_DISP_CWMAC = _METRICS.counter("device.dispatches.cwmac.mac2")

U32 = jnp.uint32


@dataclass
class SealedChunk:
    """Fixed-shape ciphertext unit flowing between stages."""
    blocks: jax.Array             # (N, 16) u32 ciphertext (or plaintext words
                                  # in plain mode)
    tag: Optional[jax.Array]      # (2,) u32 CW-MAC or None
    counter: int                  # per-stream chunk counter -> nonce
    meta: Tuple                   # tensor framing (shape, dtype, pad)
    n_words: int                  # valid words before block padding
    epoch: int = 0                # key epoch assigned at ingress; every
                                  # edge seals this chunk under ITS epoch
                                  # (counters are epoch-local — resealing
                                  # under a later epoch would reuse that
                                  # epoch's (key, nonce) pairs)


def _words_to_blocks(words: jax.Array) -> Tuple[jax.Array, int]:
    n = words.shape[0]
    n_blocks = (n + 15) // 16
    padded = jnp.pad(words, (0, n_blocks * 16 - n))
    return padded.reshape(n_blocks, 16), n


def seal_tensor(key, counter: int, x: jax.Array,
                epoch: Optional[int] = None) -> SealedChunk:
    """Seal under ``key`` at ``epoch`` (the handle's current epoch when
    None — ingress; executors pass the chunk's own epoch through)."""
    if epoch is None:
        epoch = _cur_epoch(key)
    k = _key_at(key, epoch)
    words, meta = aead.tensor_to_words(x)
    nonce = jnp.asarray(k.nonce(counter))
    ct, tag = aead.seal(jnp.asarray(k.key), nonce, words)
    blocks, n = _words_to_blocks(ct)
    return SealedChunk(blocks=blocks, tag=tag, counter=counter, meta=meta,
                       n_words=n, epoch=epoch)


def open_tensor(key, chunk: SealedChunk) -> Tuple[jax.Array, jax.Array]:
    k = _key_at(key, chunk.epoch)
    nonce = jnp.asarray(k.nonce(chunk.counter))
    ct = chunk.blocks.reshape(-1)[:chunk.n_words]
    pt, ok = aead.open_(jnp.asarray(k.key), nonce, ct, chunk.tag)
    return aead.words_to_tensor(pt, chunk.meta), ok


@dataclass
class SealedWindow:
    """A batch of same-framing sealed chunks kept as ONE pair of device
    arrays — the streaming engine's unit of flow.

    Keeping the window batched end to end is what makes the engine fast
    on top of the batched AEAD primitives: rows are never re-split into
    per-chunk device arrays between stages (per-row slicing costs one
    eager dispatch per row per hop), only gathered at worker fan-out and
    materialized at the sink.  ``counters``/``epochs`` are host-side
    per-row metadata; a window straddling a rekey flip simply carries
    mixed ``epochs`` and is opened with per-row keys.
    """
    words: jax.Array              # (B, n_words) u32 payload rows (ct, or
                                  # plaintext words in plain mode)
    tags: Optional[jax.Array]     # (B, 2) u32 CW-MAC tags or None
    counters: List[int]           # per-row chunk counters -> nonces
    epochs: List[int]             # per-row ingress epochs
    meta: Tuple                   # shared tensor framing (shape, dtype, pad)
    n_words: int

    def __len__(self) -> int:
        return len(self.counters)

    def select(self, idxs: Sequence[int]) -> "SealedWindow":
        """Row-gather a sub-window (ONE device gather per array)."""
        idx = jnp.asarray(np.asarray(idxs, np.int32))
        return SealedWindow(
            words=self.words[idx],
            tags=None if self.tags is None else self.tags[idx],
            counters=[self.counters[i] for i in idxs],
            epochs=[self.epochs[i] for i in idxs],
            meta=self.meta, n_words=self.n_words)


def _blocks_batch(words: jax.Array) -> jax.Array:
    """(B, n_words) u32 -> (B, n_blocks, 16) zero-padded block rows."""
    B, n = words.shape
    n_blocks = (n + 15) // 16
    return jnp.pad(words, ((0, 0), (0, n_blocks * 16 - n))) \
        .reshape(B, n_blocks, 16)


def window_from_chunks(chunks: Sequence[SealedChunk]) -> SealedWindow:
    """Stack a uniform chunk group into one window (per-chunk interop /
    test path — B row slices; the streaming engine never calls this in
    steady state)."""
    return SealedWindow(
        words=jnp.stack([c.blocks.reshape(-1)[:c.n_words] for c in chunks]),
        tags=None if chunks[0].tag is None
        else jnp.stack([c.tag for c in chunks]),
        counters=[c.counter for c in chunks],
        epochs=[c.epoch for c in chunks],
        meta=chunks[0].meta, n_words=chunks[0].n_words)


def window_to_chunks(win: SealedWindow) -> List[SealedChunk]:
    """Materialize per-chunk views of a window (sink/interop path)."""
    blocks = _blocks_batch(win.words)
    return [SealedChunk(blocks=blocks[b],
                        tag=None if win.tags is None else win.tags[b],
                        counter=win.counters[b], meta=win.meta,
                        n_words=win.n_words, epoch=win.epochs[b])
            for b in range(len(win))]


def _window_cipher_params(key, win: SealedWindow
                          ) -> Tuple[jax.Array, jax.Array]:
    """(keys, nonces) for a window under ``key`` at each row's ingress
    epoch.  Single-epoch windows (the steady state) share one (8,) key —
    the cheaper shared-key compiled program; mixed-epoch windows (rekey
    flips mid-window) get per-row (B, 8) keys so no row is ever
    sealed/opened under another epoch's keystream."""
    if len(set(win.epochs)) == 1:
        k = _key_at(key, win.epochs[0])
        keys = jnp.asarray(k.key)
        nonces = np.stack([np.asarray(k.nonce(c)) for c in win.counters])
    else:
        ks = [_key_at(key, e) for e in win.epochs]
        keys = jnp.asarray(np.stack([np.asarray(k.key) for k in ks]))
        nonces = np.stack([np.asarray(k.nonce(c))
                           for k, c in zip(ks, win.counters)])
    return keys, jnp.asarray(nonces)


def _reseal_coords(win: SealedWindow, reseal_as
                   ) -> Tuple[SealedWindow, List[int], List[int]]:
    """Resolve the OUTBOUND cipher coordinates of a window dispatch.

    ``reseal_as`` is ``None`` (steady state: re-seal under the rows'
    ingress coordinates) or ``(counters, epoch)`` — a freshly reserved
    contiguous counter block at one epoch (``EdgeHandle.reserve_window``)
    that a fault-tolerant re-execution seals under instead, because the
    ingress coordinates were already spent on the outbound key by the
    first dispatch of this share.  Returns (a coordinate *view* window
    for ``_window_cipher_params``, out counters, out epochs).
    """
    if reseal_as is None:
        return win, win.counters, win.epochs
    counters, epoch = reseal_as
    out_counters = [int(c) for c in counters]
    if len(out_counters) != len(win):
        raise ValueError(
            f"reseal_as carries {len(out_counters)} counters for a "
            f"{len(win)}-row window — a re-executed share must reserve "
            f"exactly one fresh counter per row")
    out_epochs = [int(epoch)] * len(win)
    view = replace(win, counters=out_counters, epochs=out_epochs)
    return view, out_counters, out_epochs


def seal_tensors_window(key, counters: Sequence[int],
                        xs: Sequence[jax.Array],
                        epoch: Optional[int] = None) -> SealedWindow:
    """Seal B same-shape tensors under ``key`` at one epoch in ONE batched
    program (``aead.seal_many``) — item-wise identical to B scalar
    :func:`seal_tensor` calls.  The ingress window path: counters come
    from a directory-reserved block (EdgeHandle.reserve_window)."""
    if epoch is None:
        epoch = _cur_epoch(key)
    k = _key_at(key, epoch)
    words, meta = aead.tensor_to_words_batch(jnp.stack(list(xs)))
    nonces = jnp.asarray(np.stack([np.asarray(k.nonce(c))
                                   for c in counters]))
    ct, tags = aead.seal_many(jnp.asarray(k.key), nonces, words)
    return SealedWindow(words=ct, tags=tags,
                        counters=[int(c) for c in counters],
                        epochs=[epoch] * len(ct), meta=meta,
                        n_words=words.shape[1])


def plain_window(counters: Sequence[int],
                 xs: Sequence[jax.Array]) -> SealedWindow:
    """Batched :func:`plain_chunk`: frame B same-shape tensors."""
    words, meta = aead.tensor_to_words_batch(jnp.stack(list(xs)))
    return SealedWindow(words=words, tags=None,
                        counters=[int(c) for c in counters],
                        epochs=[0] * words.shape[0], meta=meta,
                        n_words=words.shape[1])


def seal_tensor_many(key, counters: Sequence[int], xs: Sequence[jax.Array],
                     epoch: Optional[int] = None) -> List[SealedChunk]:
    """Chunk-list view of :func:`seal_tensors_window` (interop/tests)."""
    return window_to_chunks(seal_tensors_window(key, counters, xs,
                                                epoch=epoch))


def open_words_many(key, chunks: Sequence[SealedChunk]
                    ) -> Tuple[jax.Array, jax.Array]:
    """Open a uniform chunk group in ONE program: -> (pt (B, n_words),
    ok (B,) device verdicts — NOT synced to host)."""
    win = window_from_chunks(chunks)
    keys, nonces = _window_cipher_params(key, win)
    return aead.open_many(keys, nonces, win.words, win.tags)


def egress_window(mode: str, key, win: SealedWindow
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Batched trusted-subscriber egress: -> ((B, *item) tensor batch,
    ok verdict vector or None in plain mode).  Verdicts stay on device."""
    if mode == "plain":
        return aead.words_to_tensor_batch(win.words, win.meta), None
    keys, nonces = _window_cipher_params(key, win)
    pt, ok = aead.open_many(keys, nonces, win.words, win.tags)
    return aead.words_to_tensor_batch(pt, win.meta), ok


def egress_many(mode: str, key, chunks: Sequence[SealedChunk]
                ) -> Tuple[List[jax.Array], Optional[jax.Array]]:
    """Batched trusted-subscriber egress of a uniform chunk group:
    -> (tensors, ok verdict vector or None in plain mode)."""
    xb, ok = egress_window(mode, key, window_from_chunks(chunks))
    return [xb[b] for b in range(len(chunks))], ok


def uniform_runs(items: Sequence, key: Callable[[Any], Any]):
    """Split a sequence into consecutive runs of identical ``key(item)``
    — each run is one batched program.  Steady-state streams are a
    single run; a ragged tail gets its own.  Yields (start_index, run)."""
    i = 0
    while i < len(items):
        j = i + 1
        sig = key(items[i])
        while j < len(items) and key(items[j]) == sig:
            j += 1
        yield i, list(items[i:j])
        i = j


def _uniform_runs(chunks: Sequence[SealedChunk]):
    """Chunk-framing runs: consecutive identical (n_words, meta)."""
    for _, group in uniform_runs(chunks, lambda c: (c.n_words, c.meta)):
        yield group


def _apply_static_words(op: str, const: float, words: jax.Array) -> jax.Array:
    """Batched mirror of :func:`_apply_static_f32` on raw payload words:
    (B, n_words) -> (B, n_words), the operator applied ONCE across every
    block row of the window."""
    B, n = words.shape
    blocks = _blocks_batch(words).reshape(-1, 16)
    out = enclave_ops.OPS[op](blocks, const)
    return out.reshape(B, -1)[:, :n]


def plain_chunk(counter: int, x: jax.Array) -> SealedChunk:
    words, meta = aead.tensor_to_words(x)
    blocks, n = _words_to_blocks(words)
    return SealedChunk(blocks=blocks, tag=None, counter=counter, meta=meta,
                       n_words=n)


def unplain_chunk(chunk: SealedChunk) -> jax.Array:
    return aead.words_to_tensor(chunk.blocks.reshape(-1)[:chunk.n_words],
                                chunk.meta)


class EnclaveExecutor:
    """Executes one stage's operator under the configured security mode.

    ``key_in``/``key_out`` are either static :class:`StageKey`s or
    KeyDirectory edge handles (repro.attest.directory.EdgeHandle): with
    handles the executor opens AND re-seals each chunk under the epoch
    the chunk was ingressed in (chunk counters are epoch-local — mixing
    a counter into a later epoch would reuse that epoch's (key, nonce)
    pairs).  A mid-stream rekey therefore drains old-epoch chunks to the
    sink under their own ratchet lineage while newly ingressed chunks
    ride the new keys.
    """

    def __init__(self, mode: str, key_in, key_out,
                 block_rows: int = 512):
        assert mode in ("plain", "encrypted", "enclave"), mode
        self.mode = mode
        self.key_in = key_in
        self.key_out = key_out
        self.block_rows = block_rows
        self.errors = 0
        # Telemetry hooks: the pipeline's worker pool stamps each executor
        # with the run's tracer and a per-worker track ("s2/w1") so the
        # open->op->seal phase spans land on that worker's timeline.
        # Spans here measure *enqueue* (dispatch is async); device time
        # lands in the pipeline's per-window sync span.
        self.tracer = NULL_TRACER
        self.track = "enclave"

    # -- generic python/jnp operator (plain + encrypted modes) --------------

    def run(self, fn: Callable[[jax.Array], jax.Array],
            chunk: SealedChunk) -> Optional[SealedChunk]:
        if self.mode == "plain":
            x = unplain_chunk(chunk)
            return plain_chunk(chunk.counter, fn(x))
        if self.mode == "encrypted":
            x, ok = open_tensor(self.key_in, chunk)
            if not bool(ok):
                self.errors += 1
                return None
            # reseal under the CHUNK's epoch (not the directory's current
            # one): counters are epoch-local, so sealing an old-epoch chunk
            # under a newer key would reuse that epoch's (key, nonce) pairs
            return seal_tensor(self.key_out, chunk.counter, fn(x),
                               epoch=chunk.epoch)
        raise ValueError(
            "enclave mode only executes registered static operators "
            "(run_static); arbitrary closures cannot be attested — "
            "the paper's no-dynamic-linking rule.")

    # -- static registered operator (all modes; enclave mode fused) ---------

    def run_static(self, op: str, const: float,
                   chunk: SealedChunk) -> Optional[SealedChunk]:
        if self.mode in ("plain", "encrypted"):
            fn = lambda x: _apply_static_f32(op, const, x)
            return self.run(fn, chunk)
        # enclave: fused decrypt->op->encrypt, VMEM-confined plaintext.
        # In and out keys both resolve at the chunk's epoch — see run().
        kin = _key_at(self.key_in, chunk.epoch)
        kout = _key_at(self.key_out, chunk.epoch)
        nonce = jnp.asarray(kin.nonce(chunk.counter))
        pad_rows = (-chunk.blocks.shape[0]) % self.block_rows
        blocks = jnp.pad(chunk.blocks, ((0, pad_rows), (0, 0)))
        # MAC check on ciphertext happens outside the enclave (it is public
        # data); the keystream offset for payload is counter0=1.
        r1, s1, r2, s2 = aead.derive_mac_keys(jnp.asarray(kin.key), nonce)
        ct_words = chunk.blocks.reshape(-1)[:chunk.n_words]
        _DISPATCHES.inc()
        _DISP_CWMAC.inc()
        ok = jnp.all(cwmac.mac2(ct_words, r1, s1, r2, s2) == chunk.tag)
        if not bool(ok):
            self.errors += 1
            return None
        out_blocks = enclave_ops.enclave_map(
            jnp.asarray(kin.key), jnp.asarray(kout.key),
            nonce, 1, blocks, op=op, const=const,
            block_rows=self.block_rows)[:chunk.blocks.shape[0]]
        # re-tag under the outbound key
        nonce_out = jnp.asarray(kout.nonce(chunk.counter))
        ro1, so1, ro2, so2 = aead.derive_mac_keys(
            jnp.asarray(kout.key), nonce_out)
        out_words = out_blocks.reshape(-1)[:chunk.n_words]
        _DISPATCHES.inc()
        _DISP_CWMAC.inc()
        tag = cwmac.mac2(out_words, ro1, so1, ro2, so2)
        return SealedChunk(blocks=out_blocks, tag=tag, counter=chunk.counter,
                           meta=chunk.meta, n_words=chunk.n_words,
                           epoch=chunk.epoch)


    # -- window-native entry points (deferred MAC verdicts) -----------------

    def run_window(self, fn: Callable[[jax.Array], jax.Array],
                   win: SealedWindow, *, reseal_as=None
                   ) -> Tuple[SealedWindow, Optional[jax.Array]]:
        """Batched :meth:`run` on a whole window: ``open_many`` -> ``fn``
        per decoded row -> ``seal_many``.

        Returns (out window, ok): a candidate output for EVERY input row
        plus a per-row device verdict vector (None in plain mode) that is
        NOT synced — MAC-failed rows carry garbage and must be dropped by
        the caller after its one-per-window host sync.  ``fn`` itself is
        applied row-wise (custom closures are not assumed vmappable); the
        static-op path (:meth:`run_static_window`) is fully vectorized.

        ``reseal_as=(counters, epoch)`` seals the OUTPUT under a freshly
        reserved counter block instead of the rows' ingress coordinates —
        the fault-tolerance retry path: the input still opens under its
        original coordinates, but re-sealing under them would re-spend a
        (key, nonce, counter) triple the first dispatch already used on
        the outbound key.  The returned window carries the new
        counters/epochs.
        """
        if self.mode == "plain":
            xb = aead.words_to_tensor_batch(win.words, win.meta)
            yb = jnp.stack([fn(xb[b]) for b in range(len(win))])
            words, meta = aead.tensor_to_words_batch(yb)
            return replace(win, words=words, meta=meta,
                           n_words=words.shape[1]), None
        if self.mode != "encrypted":
            raise ValueError(
                "enclave mode only executes registered static operators "
                "(run_static_window); arbitrary closures cannot be "
                "attested — the paper's no-dynamic-linking rule.")
        out_view, out_ctrs, out_epochs = _reseal_coords(win, reseal_as)
        with self.tracer.span("enclave.open", cat="dispatch",
                              track=self.track, rows=len(win)):
            keys_in, nonces_in = _window_cipher_params(self.key_in, win)
            pt, ok = aead.open_many(keys_in, nonces_in, win.words, win.tags)
        with self.tracer.span("enclave.op", cat="dispatch",
                              track=self.track, rows=len(win)):
            xb = aead.words_to_tensor_batch(pt, win.meta)
            yb = jnp.stack([fn(xb[b]) for b in range(len(win))])
            words, meta = aead.tensor_to_words_batch(yb)
        with self.tracer.span("enclave.seal", cat="dispatch",
                              track=self.track, rows=len(win)):
            keys_out, nonces_out = _window_cipher_params(self.key_out,
                                                         out_view)
            ct, tags = aead.seal_many(keys_out, nonces_out, words)
        return replace(win, words=ct, tags=tags, meta=meta,
                       n_words=words.shape[1], counters=out_ctrs,
                       epochs=out_epochs), ok

    def run_static_window(self, op: str, const: float, win: SealedWindow,
                          *, reseal_as=None
                          ) -> Tuple[SealedWindow, Optional[jax.Array]]:
        """Batched :meth:`run_static` on a whole window (deferred
        verdicts, see :meth:`run_window`): the steady-state hot path — a
        handful of device dispatches per window regardless of B.

        encrypted: ``open_many`` -> the op applied once across all block
        rows -> ``seal_many``.  enclave: batched ciphertext MAC check +
        one ``enclave_map_rows`` grid sweep (per-row nonce/counter, and
        per-row keys when the window straddles a rekey epoch flip), so
        plaintext stays VMEM-confined row by row.  ``reseal_as`` seals
        the output under a fresh counter block (see :meth:`run_window`);
        in enclave mode the fused kernel re-encrypts directly under the
        outbound coordinates, so plaintext stays VMEM-confined on the
        retry path too.
        """
        if self.mode == "plain":
            return replace(win, words=_apply_static_words(
                op, const, win.words)), None
        out_view, out_ctrs, out_epochs = _reseal_coords(win, reseal_as)
        keys_in, nonces_in = _window_cipher_params(self.key_in, win)
        keys_out, nonces_out = _window_cipher_params(self.key_out, out_view)
        if self.mode == "encrypted":
            with self.tracer.span("enclave.open", cat="dispatch",
                                  track=self.track, rows=len(win)):
                pt, ok = aead.open_many(keys_in, nonces_in,
                                        win.words, win.tags)
            with self.tracer.span("enclave.op", cat="dispatch",
                                  track=self.track, op=op, rows=len(win)):
                words = _apply_static_words(op, const, pt)
            with self.tracer.span("enclave.seal", cat="dispatch",
                                  track=self.track, rows=len(win)):
                ct, tags = aead.seal_many(keys_out, nonces_out, words)
            return replace(win, words=ct, tags=tags, counters=out_ctrs,
                           epochs=out_epochs), ok
        # enclave: MAC check on ciphertext happens outside the enclave
        # (public data), batched: one mac-key derivation + one MAC program.
        B, n_words = len(win), win.n_words
        n_blocks = (n_words + 15) // 16
        with self.tracer.span("enclave.open", cat="dispatch",
                              track=self.track, rows=B):
            mk_in = aead.derive_mac_keys_many(keys_in, nonces_in)
            ok = jnp.all(aead.mac2_many(win.words, mk_in) == win.tags,
                         axis=-1)
        # fused decrypt->op->encrypt over the window's flattened rows;
        # payload keystream offset is counter0=1 per chunk.
        with self.tracer.span("enclave.op", cat="dispatch",
                              track=self.track, op=op, rows=B):
            rows = _blocks_batch(win.words).reshape(-1, 16)
            row_nonces = jnp.repeat(nonces_in, n_blocks, axis=0)
            row_ctrs = jnp.tile(jnp.arange(1, n_blocks + 1, dtype=U32), B)
            row_kin = keys_in if keys_in.ndim == 1 \
                else jnp.repeat(keys_in, n_blocks, axis=0)
            row_kout = keys_out if keys_out.ndim == 1 \
                else jnp.repeat(keys_out, n_blocks, axis=0)
            kw = {}
            if reseal_as is not None:
                # the fused kernel re-encrypts under the FRESH coordinates
                # (per-block keystream counters stay 1..n_blocks — the
                # chunk counter only enters through the nonce)
                kw["nonces_out"] = jnp.repeat(nonces_out, n_blocks, axis=0)
            out_words = enclave_ops.enclave_map_rows(
                row_kin, row_kout, row_nonces, row_ctrs, rows, op=op,
                const=const, **kw).reshape(B, -1)[:, :n_words]
        # re-tag under the outbound keys, batched
        with self.tracer.span("enclave.seal", cat="dispatch",
                              track=self.track, rows=B):
            mk_out = aead.derive_mac_keys_many(keys_out, nonces_out)
            tags_out = aead.mac2_many(out_words, mk_out)
        return replace(win, words=out_words, tags=tags_out,
                       counters=out_ctrs, epochs=out_epochs), ok

    # -- chunk-list wrappers over the window entry points -------------------

    def run_many(self, fn: Callable[[jax.Array], jax.Array],
                 chunks: Sequence[SealedChunk]
                 ) -> Tuple[List[SealedChunk], Optional[jax.Array]]:
        """Chunk-list view of :meth:`run_window` (interop/tests): splits
        into uniform-framing runs, returns candidate outputs for every
        row + the concatenated deferred verdict vector."""
        return self._many(lambda w: self.run_window(fn, w), chunks)

    def run_static_many(self, op: str, const: float,
                        chunks: Sequence[SealedChunk]
                        ) -> Tuple[List[SealedChunk], Optional[jax.Array]]:
        """Chunk-list view of :meth:`run_static_window` (interop/tests)."""
        return self._many(
            lambda w: self.run_static_window(op, const, w), chunks)

    def _many(self, call, chunks):
        outs: List[SealedChunk] = []
        oks: List[jax.Array] = []
        for group in _uniform_runs(chunks):
            win, ok = call(window_from_chunks(group))
            outs.extend(window_to_chunks(win))
            if ok is None:
                ok = jnp.ones((len(group),), bool)
            oks.append(ok)
        if self.mode == "plain":
            return outs, None
        return outs, oks[0] if len(oks) == 1 else jnp.concatenate(oks)


def _apply_static_f32(op: str, const: float, x: jax.Array) -> jax.Array:
    """jnp mirror of the kernel's static op registry (on decoded tensors)."""
    words, meta = aead.tensor_to_words(x)
    blocks, n = _words_to_blocks(words)
    out = enclave_ops.OPS[op](blocks, const)
    return aead.words_to_tensor(out.reshape(-1)[:n], meta)


def ingress(mode: str, key: StageKey, counter: int,
            x: jax.Array) -> SealedChunk:
    """Bring a source tensor into the pipeline under the security mode."""
    if mode == "plain":
        return plain_chunk(counter, x)
    return seal_tensor(key, counter, x)


def egress(mode: str, key: StageKey, chunk: SealedChunk):
    """Take a result out of the pipeline (trusted subscriber)."""
    if mode == "plain":
        return unplain_chunk(chunk), jnp.bool_(True)
    return open_tensor(key, chunk)
