"""Rx-style Observable combinators over chunked tensor streams.

The paper builds pipelines from RxLua observables (``:map/:filter/:reduce/
:subscribe``, Listing 2).  The TPU-native translation: a *stream* is a
sequence of fixed-shape chunks (dict of arrays or a single array); each
operator is a pure jnp function over a chunk (vectorized — one chunk is the
unit of enclave transfer, paper Fig. 4); ``filter`` is dense (validity
mask), because dataflow on accelerators cannot drop rows dynamically.

Example (the paper's Listing-2 average-age program)::

    (Observable.from_chunks(people)
        .map(lambda c: c["age"])
        .filter(lambda age: age > 18)
        .reduce(lambda acc, age, m: {"sum": acc["sum"] + (age*m).sum(),
                                     "count": acc["count"] + m.sum()},
                init={"sum": 0.0, "count": 0.0})
        .subscribe(on_next=..., on_complete=...))
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

Chunk = Any  # array or dict-of-arrays


@dataclass(frozen=True)
class Op:
    """One node of an operator chain.

    Shared vocabulary between this plaintext Observable layer and the
    secure-pipeline DSL (:mod:`repro.dsl.builder`): the DSL's fluent
    chain is a tuple of these same nodes, with ``meta`` carrying the
    paper's Listing-1 stage attributes (``name``, ``workers``, ``sgx``
    placement, static ``op``/``const``).  ``describe_ops`` renders either
    chain identically; ``StreamBuilder.as_observable`` lowers a DSL chain
    back onto an Observable (the cleartext oracle).
    """
    kind: str                     # map | filter | reduce | window | key_by
    fn: Optional[Callable] = None
    init: Any = None
    meta: Dict[str, Any] = field(default_factory=dict)


def describe_ops(ops: Tuple[Op, ...]) -> str:
    """One-line summary of an op chain — ``map(identity)[w=4,sgx] ->
    filter(delay_filter_u32) -> reduce`` — shared by
    :meth:`Observable.describe` and ``StreamBuilder.describe`` so the
    two layers print pipelines in one vocabulary."""
    parts = []
    for o in ops:
        name = o.meta.get("op") or getattr(o.fn, "__name__", None) \
            or o.meta.get("reducer") or ""
        label = f"{o.kind}({name})" if name and name != "<lambda>" \
            else o.kind
        attrs = []
        if o.meta.get("workers", 1) != 1:
            attrs.append(f"w={o.meta['workers']}")
        if o.meta.get("sgx"):
            attrs.append("sgx")
        if attrs:
            label += f"[{','.join(attrs)}]"
        parts.append(label)
    return " -> ".join(parts) if parts else "(empty)"


class Observable:
    """A lazily-composed operator chain over a chunk source."""

    def __init__(self, source: Iterable[Chunk], ops: Tuple[Op, ...] = ()):
        self._source = source
        self._ops = ops

    # ---------------------------------------------------------- constructors

    @staticmethod
    def from_chunks(chunks: Iterable[Chunk]) -> "Observable":
        return Observable(chunks)

    @staticmethod
    def from_array(x, chunk_rows: int) -> "Observable":
        n_full, rem = divmod(x.shape[0], chunk_rows)

        def gen():
            for i in range(n_full):
                yield x[i * chunk_rows:(i + 1) * chunk_rows]
            if rem:  # ragged tail chunk — rows must not be dropped
                yield x[n_full * chunk_rows:]
        return Observable(gen())

    # ------------------------------------------------------------- operators

    def _with(self, op: Op) -> "Observable":
        return Observable(self._source, self._ops + (op,))

    def map(self, fn: Callable[[Chunk], Chunk]) -> "Observable":
        return self._with(Op("map", fn))

    def filter(self, pred: Callable[[Chunk], jax.Array]) -> "Observable":
        """Dense filter: downstream sees (chunk, mask)."""
        return self._with(Op("filter", pred))

    def reduce(self, fn: Callable[[Any, Chunk, jax.Array], Any],
               init: Any) -> "Observable":
        return self._with(Op("reduce", fn, init=init))

    def window(self, n_chunks: int) -> "Observable":
        return self._with(Op("window", meta={"n": n_chunks}))

    def key_by(self, key_fn: Callable[[Chunk], jax.Array],
               num_keys: int) -> "Observable":
        return self._with(Op("key_by", key_fn, meta={"num_keys": num_keys}))

    # ------------------------------------------------------------- execution

    def subscribe(self, on_next: Optional[Callable] = None,
                  on_error: Optional[Callable] = None,
                  on_complete: Optional[Callable] = None) -> Any:
        """Drive the stream to completion (observer pattern, paper §4)."""
        state = {"reduce": None, "reduce_init": False, "window": []}
        final = None
        try:
            for chunk in self._source:
                result = self._apply_ops(chunk, state)
                if result is not None and on_next is not None:
                    on_next(result)
                final = result if result is not None else final
        except Exception as e:  # noqa: BLE001 — surfaced to the observer
            if on_error is not None:
                on_error(e)
                return None
            raise
        if state["reduce_init"]:
            final = state["reduce"]
            if on_next is not None:
                on_next(final)
        if on_complete is not None:
            on_complete()
        return final

    def _apply_ops(self, chunk: Chunk, state: Dict) -> Optional[Chunk]:
        mask = None
        for op in self._ops:
            if op.kind == "map":
                chunk = op.fn(chunk)  # maps are maskwise-transparent
            elif op.kind == "filter":
                m = op.fn(chunk)
                mask = m if mask is None else (mask & m)
            elif op.kind == "reduce":
                if not state["reduce_init"]:
                    state["reduce"] = op.init
                    state["reduce_init"] = True
                m = mask if mask is not None else None
                state["reduce"] = op.fn(state["reduce"], chunk, m)
                return None  # reduce swallows chunks; emits at complete
            elif op.kind == "window":
                state["window"].append((chunk, mask))
                if len(state["window"]) < op.meta["n"]:
                    return None
                chunks = state["window"]
                state["window"] = []
                chunk = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                     *[c for c, _ in chunks])
                masks = [m for _, m in chunks]
                mask = None if masks[0] is None else jnp.concatenate(masks)
            elif op.kind == "key_by":
                keys = op.fn(chunk)
                chunk = {"data": chunk, "keys": keys}
        if mask is not None:
            return {"data": chunk, "mask": mask}
        return chunk

    # ------------------------------------------------------------ inspection

    @property
    def ops(self) -> Tuple[Op, ...]:
        return self._ops

    def describe(self) -> str:
        """One-line op-chain summary (see :func:`describe_ops`)."""
        return describe_ops(self._ops)
