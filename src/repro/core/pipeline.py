"""Pipeline builder: stages + routers -> an executable secure dataflow.

Mirrors the paper's Compose description (Listing 1): a pipeline is a list
of named stages, each with an operator, a worker count, and a placement
("sgx" workers are the ones whose operator runs under the enclave
executor).  Routers between stages apply fair-queue (in) / round-robin
(out) chunk scheduling — repro.core.router.

Execution is streaming and **window-vectorized**: the unit of device work
is a window of ``window_chunks`` chunks per worker, not a chunk.  Ingress
seals whole windows with the batched AEAD fast path behind a small
prefetch/double-buffer (window N+1's seal is dispatched before window N
is handed downstream, so it overlaps downstream compute via JAX async
dispatch), with nonce-counter blocks reserved per window from the
directory.  Each stage dispatches every worker's per-window queue as ONE
batched open -> operator -> seal program chain
(:meth:`repro.core.enclave.EnclaveExecutor.run_static_many`), and MAC
verdicts are **deferred**: per-row verdicts stay on device and sync to
host once per window — failed rows are dropped there and counted as
``mac_failures`` — instead of one blocking ``bool()`` per chunk.
``window_chunks=1`` degenerates to the original per-chunk engine and is
kept as the bit-identical oracle.  Batched programs live in the AEAD
shape-keyed compile cache, so steady-state streaming compiles nothing.

Per-edge session keys come from a ``repro.attest.KeyDirectory``: every
stage worker is measured (repro.attest.measure), enrolled, and admitted
only if its quote verifies, and edge keys are established by the attested
handshake — the trust bootstrap the paper assumes pre-done.
``run(rekey_every_n=...)`` rotates every edge key mid-stream (epoch
ratchet); a window straddling a flip opens every row under its ingress
epoch (per-row keys — rows never cross keystreams), and
``KeyDirectory.revoke`` evicts a worker live — subsequent windows skip
it.  Per-stage counters, byte totals, and MAC failures feed the
benchmarks (paper Fig. 6/7/8); ``StageMetrics.seconds`` is measured at
window granularity around a ``block_until_ready``, so throughput numbers
time execution, not async enqueue.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.attest.directory import (EdgeHandle, KeyDirectory,
                                    KeyDirectoryError)
from repro.attest.measure import IO_ENDPOINT, measure_stage
from repro.attest.quote import QuoteError
from repro.configs.base import SecureStreamConfig
from repro.core import router as R
from repro.core.enclave import (EnclaveExecutor, SealedChunk, SealedWindow,
                                egress, egress_window, ingress, plain_window,
                                seal_tensors_window, uniform_runs)
from repro.obs.metrics import (REGISTRY as _METRICS, dispatch_count,
                               reset_dispatch_count)  # noqa: F401 (re-export)
from repro.obs.monitor import NULL_MONITOR
from repro.obs.trace import NULL_TRACER


@dataclass
class Stage:
    """One named pipeline stage — the paper's Listing-1 unit.

    ``op`` names a statically registered operator
    (``repro.kernels.enclave_map.ops.OPS`` — the only code attestable
    under ``mode="enclave"``) or ``"custom"`` when ``fn``/``reduce_fn``
    carries a Python callable (plain/encrypted modes only).  ``workers``
    is the stage's fan-out pool size; ``sgx`` is the paper's
    ``constraint:type==sgx`` placement flag (non-sgx stages run on the
    encrypted, non-enclave path when the pipeline mode is ``enclave``).
    A stage with ``reduce_fn`` is terminal: it folds decrypted chunks at
    the trusted sink edge, seeded with ``reduce_init``.

    Stages are usually not built by hand anymore — ``repro.dsl.stream``
    / ``repro.dsl.load_spec`` compile to this dataclass (bit-identically;
    the hand-built form is kept as the tests' parity oracle).
    """
    name: str
    op: str                              # static registry op name, or "custom"
    const: float = 0.0
    fn: Optional[Callable] = None        # custom fn (plain/encrypted only)
    workers: int = 1
    sgx: bool = True                     # paper: constraint:type==sgx
    reduce_fn: Optional[Callable] = None # terminal reduce (runs at egress)
    reduce_init: Any = None


@dataclass
class StageMetrics:
    """Per-stage counters behind ``Pipeline.report()`` (paper Fig. 6-8):
    surviving chunks, payload bytes, execution seconds (measured around a
    ``block_until_ready`` at window granularity), MAC failures (dropped
    rows), and per-worker chunk counts from the round-robin fan-out."""
    chunks: int = 0
    bytes: int = 0
    seconds: float = 0.0
    mac_failures: int = 0
    # chunks handled per worker of the stage (round-robin fan-out accounting;
    # survives rescaling — scale_stage pads/keeps this list).
    per_worker: List[int] = field(default_factory=list)
    # window rounds processed and compiled-program launches attributed to
    # them (the megakernel item's per-hop regression signal: fusing this
    # stage's open->op->seal chain must DROP dispatches_per_window)
    windows: int = 0
    dispatches: int = 0

    @property
    def dispatches_per_window(self) -> Optional[float]:
        if self.windows == 0:
            return None
        return self.dispatches / self.windows

    @property
    def throughput_mbps(self) -> Optional[float]:
        """Payload MB/s over the stage's measured execution seconds.

        ``None`` means *nothing was measured yet* (no execution seconds
        recorded) — distinct from a genuine ``0.0``, which means time
        passed but no payload survived (every row MAC-failed)."""
        if self.seconds <= 0.0:
            return None
        return (self.bytes / 1e6) / self.seconds

    @property
    def mac_failure_rate(self) -> Optional[float]:
        """Fraction of rows this stage dropped to MAC failures; ``None``
        before the stage has seen any row at all."""
        seen = self.chunks + self.mac_failures
        if seen == 0:
            return None
        return self.mac_failures / seen


# One host rendezvous per window (deferred-verdict sync + block on the
# window's outputs).  A regression back to per-chunk syncing shows up as
# this counter growing with the chunk count instead of the window count.
# Registered in the process-wide metrics registry; the module-level
# functions below are the original API, kept as thin shims.
_HOST_SYNCS = _METRICS.counter("pipeline.host_syncs")


def host_sync_count() -> int:
    """Device->host synchronisation rendezvous performed by the streaming
    engine (one per window).  Tests assert one sync per window.  Shim
    over the registered counter ``pipeline.host_syncs``."""
    return int(_HOST_SYNCS.value)


def reset_host_sync_count() -> None:
    """Zero the rendezvous counter (test setup)."""
    _HOST_SYNCS.reset()


# Compiled-program launches (incremented at every eager launch site:
# aead fastpath, enclave_map, eager cwmac, dist.exchange).  The engine
# reads deltas around each window round to attribute launches per stage
# hop; ``dispatch_count()``/``reset_dispatch_count()`` (re-exported above
# from repro.obs.metrics) are the process-wide shims next to
# ``host_sync_count()``.
_DISPATCHES = _METRICS.counter("device.dispatches")


def _shape_runs(xs: List[jax.Array]):
    """Consecutive same-(shape, dtype) runs of a tensor list — each run
    frames as one batched window (ragged tails get their own)."""
    return uniform_runs(xs, lambda x: (x.shape, x.dtype))


def _sync_window(outputs: List[jax.Array],
                 vec_specs: List[Tuple[Optional[jax.Array], int]],
                 tracer=NULL_TRACER, track: str = "main") -> np.ndarray:
    """THE one host sync of a window: block until the window's outputs are
    ready and materialize every deferred MAC verdict in a single
    transfer.  ``vec_specs`` is [(device verdict vector or None, n)];
    None (plain mode) counts as all-pass.  The ``sync.verdicts`` span is
    where device time surfaces on a timeline — dispatch spans upstream
    only measure (async) enqueue."""
    _HOST_SYNCS.inc()
    with tracer.span("sync.verdicts", cat="sync", track=track,
                     rows=sum(n for _, n in vec_specs)):
        if outputs:
            jax.block_until_ready(outputs)
        if all(ok is None for ok, _ in vec_specs):
            return np.ones(sum(n for _, n in vec_specs), bool)
        parts = [jnp.ones((n,), bool) if ok is None else ok
                 for ok, n in vec_specs]
        vec = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return np.asarray(vec)


class Pipeline:
    """An executable secure dataflow: ordered :class:`Stage` list +
    routers + per-edge attested session keys, streamed by the
    window-vectorized engine (see the module docstring for the execution
    model and its invariants — epoch-carrying chunks, directory-reserved
    nonce-counter blocks, counter continuation across ``run()`` calls).

    ``fusion`` is builder metadata from ``repro.dsl.compile``: a
    ``{"fused_from": {survivor: [absorbed stage names]}, "decisions":
    [...]}`` record of bit-exact stage merges, surfaced via
    :meth:`report` — hand-built pipelines simply leave it empty.
    """

    def __init__(self, stages: Sequence[Stage],
                 secure: SecureStreamConfig = SecureStreamConfig(),
                 seed: int = 0,
                 directory: Optional[KeyDirectory] = None,
                 window_chunks: int = 8,
                 fusion: Optional[Dict[str, Any]] = None,
                 tracer=None,
                 monitor=None,
                 retry=None,
                 chaos=None):
        self.stages = list(stages)
        self.secure = secure
        self.seed = seed
        # span tracing is strictly off by default: NULL_TRACER's span()
        # returns a shared no-op context manager, so the instrumented
        # paths cost an attribute call when tracing is disabled
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # live health monitoring follows the same contract: NULL_MONITOR
        # is enabled=False, so the per-window record is one attr check
        self.monitor = monitor if monitor is not None else NULL_MONITOR
        # fault tolerance is opt-in the same way: ``retry`` is a
        # repro.ft.retry.RetryPolicy, ``chaos`` a repro.ft.chaos.ChaosPlan
        # (fault injection for tests/benchmarks).  When both are None the
        # engine runs the original non-FT stage stream untouched.
        self.retry = retry
        self.chaos = chaos
        self._last_ft = None        # FTContext of the most recent run
        # dispatch/window accounting for the ingress and egress hops
        # (stage hops live in StageMetrics)
        self._ingress_windows_n = 0
        self._ingress_dispatches = 0
        self._egress_windows_n = 0
        self._egress_dispatches = 0
        # worker ids whose eviction has already been audit-logged (the
        # engine records each revoked worker's first skipped dispatch once)
        self._evicted_logged: set = set()
        # DSL-compiler provenance (stage merges); never read on the hot path
        self.fusion: Dict[str, Any] = dict(fusion or {})
        # chunks per worker per window: each worker's queue of a window is
        # ONE batched device dispatch. 1 = the per-chunk oracle engine.
        self.window_chunks = max(1, int(window_chunks))
        # The directory owns every session key; passing one in (scale_stage,
        # shared trust domain) carries sessions, epoch, and revocations over.
        self.directory = directory if directory is not None \
            else KeyDirectory(seed=seed)
        self._setup_attestation()
        # edge i connects stage i-1 -> i (+ source and sink); handles pull
        # the live epoch key from the directory on every seal/open.  Plain
        # mode never touches a key, so it skips the edge handshakes
        # entirely (workers are still measured and admitted).
        self.keys: List[Optional[EdgeHandle]] = [
            self.directory.handle(f"edge{i}")
            for i in range(len(self.stages) + 1)
        ] if secure.mode != "plain" else [None] * (len(self.stages) + 1)
        self.metrics: Dict[str, StageMetrics] = {
            s.name: StageMetrics() for s in self.stages}
        self.monitor.attach(self)

    # -------------------------------------------------------- attestation

    @staticmethod
    def worker_id(stage_name: str, w: int) -> str:
        """Directory identity of worker ``w`` of a stage — the id
        ``KeyDirectory.revoke`` takes to evict it live."""
        return f"{stage_name}/w{w}"

    def _setup_attestation(self) -> None:
        """Measure + enroll every endpoint and worker, verify quotes, and
        establish per-edge session keys via the attested handshake.

        Revoked worker ids stay quarantined (they are neither re-enrolled
        nor admitted — scale_stage cannot resurrect them); existing edge
        sessions are reused so a rescale does not re-key the stream.
        """
        d = self.directory
        S = len(self.stages)
        endpoints = ["io/source"] + [f"stage/{s.name}" for s in self.stages] \
            + ["io/sink"]
        d.enroll("io/source", IO_ENDPOINT, allow=True)
        d.enroll("io/sink", IO_ENDPOINT, allow=True)
        for st in self.stages:
            m = measure_stage(op=st.op, const=st.const, fn=st.fn, sgx=st.sgx)
            d.policy.allow(m)
            d.enroll(f"stage/{st.name}", m)
            for w in range(max(1, st.workers)):
                wid = self.worker_id(st.name, w)
                if d.policy.is_revoked(wid):
                    continue                     # stays evicted
                d.enroll(wid, m)
                d.admit(wid)                     # raises unless quote verifies
        if self.secure.mode == "plain":
            return                               # no keys -> no handshakes
        for i in range(S + 1):
            if not d.has_session(f"edge{i}"):
                d.establish(f"edge{i}", endpoints[i], endpoints[i + 1],
                            stage_id=i)

    def _live_workers(self, st: Stage) -> List[int]:
        """Worker indices still dispatchable.

        Full quote admission (sign + verify) happened at build/rescale;
        the only bit that can flip mid-stream is revocation, so the
        per-window check is a set lookup, not a re-attestation.
        """
        live = []
        for w in range(max(1, st.workers)):
            wid = self.worker_id(st.name, w)
            if self.directory.policy.is_revoked(wid):
                if wid not in self._evicted_logged:
                    self._evicted_logged.add(wid)
                    self.directory.audit.record("eviction", worker=wid,
                                                stage=st.name)
                continue
            live.append(w)
        if not live:
            # deliberately NOT RevokedWorkerError: a stage name is not a
            # worker id, and the ft supervisor revokes e.worker_id
            raise KeyDirectoryError(
                f"every worker of stage {st.name!r} is revoked or "
                f"inadmissible — nothing can process the edge")
        return live

    # ------------------------------------------------------------------ run

    def _worker_pool(self, i: int, st: Stage) -> List[EnclaveExecutor]:
        """One executor per worker of stage i (paper: W identical workers
        behind the stage's inbound router, all sharing the edge keys)."""
        mode = self.secure.mode
        st_mode = mode if st.sgx else ("plain" if mode == "plain"
                                       else "encrypted")
        pool = [EnclaveExecutor(st_mode, self.keys[i], self.keys[i + 1])
                for _ in range(max(1, st.workers))]
        for w, ex in enumerate(pool):
            ex.tracer = self.tracer
            ex.track = f"{st.name}/w{w}"
        return pool

    def _stage_stream(self, upstream: Iterator[SealedWindow], st: Stage,
                      pool: List[EnclaveExecutor],
                      window_chunks: int) -> Iterator[SealedWindow]:
        """Fan a window stream across the stage's workers.

        Windows flow as batched device arrays; each round accumulates
        ``len(live) * window_chunks`` rows, round-robins them over the
        worker pool by rolling global row index (paper's Push socket —
        row g goes to worker g mod W, exactly the per-chunk engine's
        assignment), and runs each worker's share as ONE batched
        open->op->seal dispatch (a device gather splits the window; the
        single-worker steady state dispatches the window untouched).  MAC
        verdicts are deferred: the whole round syncs to host ONCE
        (`_sync_window`), failed rows are dropped (reactive on_error
        semantics) and counted, and survivors flow downstream in original
        stream order — the rr->fq composition of the per-chunk engine,
        minus dropped rows.  Revocation is re-checked per round
        (including revocations triggered while the window was being
        pulled), so a worker revoked mid-stream stops receiving rows at
        the next dispatch.
        """
        m = self.metrics[st.name]
        if len(m.per_worker) < len(pool):
            m.per_worker.extend([0] * (len(pool) - len(m.per_worker)))
        tr = self.tracer
        audit = self.directory.audit
        # instruments resolved ONCE per stage stream, not per window
        lat = _METRICS.histogram(f"pipeline.stage.{st.name}.window_seconds")
        depth = _METRICS.gauge(f"pipeline.stage.{st.name}.queue_rows")
        phase = 0                    # rolling global row index for rr
        while True:
            live = self._live_workers(st)
            target = len(live) * window_chunks
            parts: List[SealedWindow] = []
            got = 0
            while got < target:
                win = next(upstream, None)
                if win is None:
                    break
                parts.append(win)
                got += len(win)
            if not parts:
                return
            depth.set(got)
            tr.counter("queue_rows", got, track=st.name)
            # pulling the window may itself have revoked workers upstream
            live = self._live_workers(st)
            L = len(live)
            d0 = _DISPATCHES.value
            t0 = time.perf_counter()
            dispatches = []          # (part idx, worker, row idxs, out, ok)
            with tr.span("stage.dispatch", cat="dispatch", track=st.name,
                         rows=got, workers=L):
                for pi, win in enumerate(parts):
                    B = len(win)
                    assign = [(phase + j) % L for j in range(B)]
                    phase += B
                    for k in range(L):
                        idxs = [j for j in range(B) if assign[j] == k]
                        if not idxs:
                            continue
                        sub = win if len(idxs) == B else win.select(idxs)
                        w = live[k]
                        if st.fn is not None:
                            out, ok = pool[w].run_window(st.fn, sub)
                        else:
                            out, ok = pool[w].run_static_window(
                                st.op, st.const, sub)
                        dispatches.append((pi, w, idxs, out, ok))
            verdicts = _sync_window(
                [d[3].words for d in dispatches],
                [(d[4], len(d[3])) for d in dispatches],
                tracer=tr, track=st.name)
            # honest window timing: t0 -> after block_until_ready, so
            # throughput_mbps reflects execution, not async enqueue
            dt = time.perf_counter() - t0
            m.seconds += dt
            lat.observe(dt)
            m.windows += 1
            disp = _DISPATCHES.value - d0
            m.dispatches += disp
            tr.counter("windows_per_s", (1.0 / dt) if dt > 0 else 0.0,
                       track=st.name)
            off = 0
            marks: List[np.ndarray] = []
            for pi, w, idxs, out, _ in dispatches:
                v = verdicts[off: off + len(idxs)]
                off += len(idxs)
                marks.append(v)
                for jj, alive in enumerate(v):
                    if alive:
                        m.chunks += 1
                        m.per_worker[w] += 1
                        m.bytes += int(parts[pi].n_words) * 4
                    else:
                        m.mac_failures += 1
                        pool[w].errors += 1
                        audit.record("mac_failure", stage=st.name,
                                     worker=self.worker_id(st.name, w),
                                     row=out.counters[jj],
                                     epoch=out.epochs[jj])
            mon = self.monitor
            if mon.enabled:
                wrows: Dict[int, int] = {}
                for _, w, idxs, _, _ in dispatches:
                    wrows[w] = wrows.get(w, 0) + len(idxs)
                mon.record_window(
                    st.name, rows=got, ok_rows=int(verdicts.sum()),
                    bytes=sum(len(p) * int(p.n_words) * 4 for p in parts),
                    seconds=dt, queue_rows=got, worker_rows=wrows,
                    min_epoch=min(min(p.epochs) for p in parts),
                    dispatches=disp)
            with tr.span("stage.merge", cat="pipeline", track=st.name,
                         windows=len(parts)):
                merged = list(self._merge_outputs(parts, dispatches, marks))
            yield from merged

    @staticmethod
    def _merge_outputs(parts, dispatches, marks):
        """Reassemble each input window's surviving rows in original
        stream order.  The all-survived single-dispatch case (steady
        state) passes the worker's output through untouched; otherwise
        one concatenate + one gather rebuilds the window."""
        for pi in range(len(parts)):
            ds = [(d, mk) for d, mk in zip(dispatches, marks)
                  if d[0] == pi]
            if not ds:
                continue
            if len(ds) == 1 and len(ds[0][0][2]) == len(parts[pi]) \
                    and bool(ds[0][1].all()):
                yield ds[0][0][3]
                continue
            outs = [d[3] for d, _ in ds]
            cat_w = outs[0].words if len(outs) == 1 \
                else jnp.concatenate([o.words for o in outs])
            cat_t = outs[0].tags
            if cat_t is not None and len(outs) > 1:
                cat_t = jnp.concatenate([o.tags for o in outs])
            entries = []             # (orig row, concat pos, counter, epoch)
            pos = 0
            for (_, _, idxs, out, _), mk in ds:
                entries.extend((j, pos + jj, out.counters[jj],
                                out.epochs[jj])
                               for jj, j in enumerate(idxs) if mk[jj])
                pos += len(idxs)
            if not entries:
                continue
            entries.sort()
            sel = jnp.asarray(np.asarray([e[1] for e in entries], np.int32))
            yield SealedWindow(
                words=cat_w[sel],
                tags=None if cat_t is None else cat_t[sel],
                counters=[e[2] for e in entries],
                epochs=[e[3] for e in entries],
                meta=outs[0].meta, n_words=outs[0].n_words)

    # ------------------------------------------------------ fault tolerance

    def _ft_fresh_coords(self, n: int):
        """Reserve a FRESH counter block for a re-execution.

        Every retry / failover / backup / replay re-seals its rows under
        counters reserved from the INGRESS edge at the current epoch —
        the one allocator whose blocks are globally collision-free across
        every edge (mid-pipeline edges never advance the session count),
        so a re-executed share can never re-spend a (key, nonce, counter)
        triple already used on any outbound key.  Plain mode has no
        nonces: returns None (re-execution keeps original coordinates).
        """
        h0 = self.keys[0]
        if h0 is None:
            return None
        base, epoch = h0.reserve_window(n)
        return (list(range(base, base + n)), epoch)

    def _ft_exec(self, st: Stage, ex: EnclaveExecutor, sub: SealedWindow,
                 coords):
        """One batched open->op->seal of a share.  ``coords`` =
        (counters, epoch) re-seals under fresh ingress-reserved
        coordinates (the re-execution path); None keeps steady state."""
        if st.fn is not None:
            return ex.run_window(st.fn, sub, reseal_as=coords)
        return ex.run_static_window(st.op, st.const, sub, reseal_as=coords)

    def _ft_pick_survivor(self, st: Stage, ft, exclude: int,
                          prefer=None) -> Optional[int]:
        """A live, not-dead worker other than ``exclude`` — honoring the
        backup dispatcher's placement hint when it is usable.

        Recomputed from the CURRENT worker set (not the round-start live
        list): a spare enrolled earlier in the same round must absorb
        later failovers instead of triggering more enrollments."""
        cands = []
        for x in range(max(1, st.workers)):
            if x == exclude or ft.is_dead(st.name, x):
                continue
            if self.directory.policy.is_revoked(self.worker_id(st.name, x)):
                continue
            cands.append(x)
        if not cands:
            return None
        if prefer is not None and prefer in cands:
            return prefer
        return cands[0]

    def enroll_spare(self, stage_name: str) -> int:
        """Enroll + admit one spare worker for a stage, live.

        The spare takes the same attested admission path as build time
        (measure -> enroll -> quote -> verify); edge sessions are
        stage-scoped (``stage/<name>`` endpoints), so the spare joins the
        existing attested channels — ``KeyDirectory.establish`` runs only
        if an edge somehow lost its session.  Returns the new worker
        index; raises :class:`repro.attest.quote.QuoteError` if admission
        fails (including a chaos-injected handshake failure).
        """
        idx, st = next((i, s) for i, s in enumerate(self.stages)
                       if s.name == stage_name)
        d = self.directory
        w = max(1, st.workers)
        wid = self.worker_id(st.name, w)
        meas = measure_stage(op=st.op, const=st.const, fn=st.fn, sgx=st.sgx)
        d.policy.allow(meas)
        d.enroll(wid, meas)
        d.admit(wid)                 # raises unless the quote verifies
        if self.secure.mode != "plain":
            endpoints = ["io/source"] \
                + [f"stage/{s.name}" for s in self.stages] + ["io/sink"]
            for e in (idx, idx + 1):
                if not d.has_session(f"edge{e}"):
                    d.establish(f"edge{e}", endpoints[e], endpoints[e + 1],
                                stage_id=e)
        st.workers = w + 1
        return w

    def _ft_enroll_spare(self, st: Stage, pool: List[EnclaveExecutor],
                         ft) -> Optional[int]:
        """Failover fallback when a stage has no survivors: enroll a
        spare through the live admission path and extend the worker pool.
        A rejected handshake (chaos ``enroll_fail``) is retried once with
        the next spare id; None if no spare could be admitted."""
        for _ in range(2):
            try:
                w = self.enroll_spare(st.name)
            except QuoteError:
                ft.enroll_failures.inc()
                continue
            i = next(ix for ix, s in enumerate(self.stages)
                     if s.name == st.name)
            mode = self.secure.mode
            st_mode = mode if st.sgx else ("plain" if mode == "plain"
                                           else "encrypted")
            ex = EnclaveExecutor(st_mode, self.keys[i], self.keys[i + 1])
            ex.tracer = self.tracer
            ex.track = f"{st.name}/w{w}"
            pool.append(ex)
            m = self.metrics[st.name]
            if len(m.per_worker) < len(pool):
                m.per_worker.extend([0] * (len(pool) - len(m.per_worker)))
            return w
        return None

    def _ft_dispatch_share(self, st: Stage, pool: List[EnclaveExecutor],
                           ft, rnd: int, w: int,
                           sub: SealedWindow, share_id: int):
        """Dispatch one worker share under the retry policy.

        Consults the chaos plan for crash/stall faults at this
        (stage, round, worker) hook, applies bounded retry with
        exponential backoff on the same worker, fails the share over to
        a survivor (or a live-enrolled spare) when the worker is gone,
        and races an injected straggler against a speculative backup
        copy on another worker.  EVERY re-execution re-seals under fresh
        ingress-reserved counters (:meth:`_ft_fresh_coords`).  Returns
        (final worker, out window, deferred verdict vector); raises if
        the share cannot be placed anywhere.
        """
        audit = self.directory.audit
        policy = ft.policy
        chaos = ft.chaos
        det = ft.detector(st.name)
        bdisp = ft.dispatcher(st.name, max(1, st.workers))
        bdisp.track(share_id, w)
        attempts = 0
        fresh = False
        t_start = time.perf_counter()
        while True:
            spec = None if chaos is None \
                else chaos.crash_for(st.name, rnd, w)
            dead = ft.is_dead(st.name, w)
            out = ok = dt = None
            if not dead and (spec is None or spec.when == "after"):
                coords = self._ft_fresh_coords(len(sub)) if fresh else None
                t0 = time.perf_counter()
                out, ok = self._ft_exec(st, pool[w], sub, coords)
                dt = time.perf_counter() - t0
            if spec is not None:
                # the fault fires exactly once: one worker_failed per
                # injected crash, regardless of how many shares it costs
                ft.worker_failures.inc()
                audit.record("worker_failed", stage=st.name,
                             worker=self.worker_id(st.name, w),
                             reason="crash", fatal=spec.fatal, round=rnd)
                if spec.fatal:
                    ft.mark_dead(st.name, w)
            if spec is not None or dead:
                # the share (or its result) is lost
                attempts += 1
                alive = not ft.is_dead(st.name, w)
                within = attempts < policy.max_attempts and (
                    policy.deadline_s is None
                    or time.perf_counter() - t_start < policy.deadline_s)
                if alive and within:
                    ft.retries.inc()
                    audit.record("share_retried", stage=st.name,
                                 worker=self.worker_id(st.name, w),
                                 attempt=attempts, round=rnd)
                    policy.sleep(policy.backoff(attempts))
                    fresh = True
                    continue
                if not policy.failover:
                    raise KeyDirectoryError(
                        f"share of stage {st.name!r} lost worker "
                        f"{self.worker_id(st.name, w)} and failover is "
                        f"disabled by the retry policy")
                w2 = self._ft_pick_survivor(st, ft, exclude=w)
                if w2 is None and policy.enroll_spare:
                    w2 = self._ft_enroll_spare(st, pool, ft)
                if w2 is None:
                    raise KeyDirectoryError(
                        f"share of stage {st.name!r} has no survivor to "
                        f"fail over to and no spare could be admitted")
                ft.failovers.inc()
                audit.record("share_failover", stage=st.name,
                             worker=self.worker_id(st.name, w),
                             to=self.worker_id(st.name, w2),
                             reason="crash", round=rnd)
                bdisp.track(share_id, w2)
                w = w2
                attempts = 0
                fresh = True
                continue
            # success path: race an injected stall against the cutoff
            stall = None if chaos is None \
                else chaos.stall_for(st.name, rnd, w)
            if stall is not None:
                observed = dt + stall.seconds
                if observed > policy.timeout_for(det):
                    ft.worker_failures.inc()
                    audit.record("worker_failed", stage=st.name,
                                 worker=self.worker_id(st.name, w),
                                 reason="stall", round=rnd)
                    hint = bdisp.reissue(share_id)
                    w2 = self._ft_pick_survivor(st, ft, exclude=w,
                                                prefer=hint)
                    if w2 is not None:
                        # speculative backup wins; the original result
                        # arrives late and deduplicates
                        ft.backups.inc()
                        audit.record("share_failover", stage=st.name,
                                     worker=self.worker_id(st.name, w),
                                     to=self.worker_id(st.name, w2),
                                     reason="backup", round=rnd)
                        coords = self._ft_fresh_coords(len(sub))
                        t0 = time.perf_counter()
                        out2, ok2 = self._ft_exec(st, pool[w2], sub,
                                                  coords)
                        det.observe(time.perf_counter() - t0)
                        bdisp.track(share_id, w2)
                        bdisp.complete(share_id)   # backup completes...
                        bdisp.complete(share_id)   # ...original is a dup
                        return w2, out2, ok2
                    # nobody to back up on: keep the slow result
                det.observe(observed)
                bdisp.complete(share_id)
                return w, out, ok
            det.observe(dt)
            bdisp.complete(share_id)
            return w, out, ok

    def _stage_stream_ft(self, upstream: Iterator[SealedWindow], st: Stage,
                         pool: List[EnclaveExecutor], window_chunks: int,
                         ft) -> Iterator[SealedWindow]:
        """Fault-tolerant sibling of :meth:`_stage_stream`.

        Same round structure (pull -> round-robin -> one batched
        dispatch per worker share -> ONE deferred-verdict host sync ->
        merge in stream order), with the fault-tolerance hooks around
        it: the round's sealed input parts are RETAINED in the replay
        buffer until its verdicts are folded in; each share dispatch
        goes through :meth:`_ft_dispatch_share` (chaos crash/stall
        hooks, retry/backoff, failover, speculative backup); tampered
        shares MAC-fail at the sync and their rows are re-executed from
        the retained clean parts; a dropped verdict sync voids the whole
        share, which is likewise replayed.  Replayed rows re-seal under
        fresh ingress counters, and the merge still orders by original
        row index — so the surviving stream, and any terminal reduce
        over it, is bit-identical to the fault-free run.
        """
        m = self.metrics[st.name]
        if len(m.per_worker) < len(pool):
            m.per_worker.extend([0] * (len(pool) - len(m.per_worker)))
        tr = self.tracer
        audit = self.directory.audit
        chaos = ft.chaos
        secure = self.secure.mode != "plain"
        lat = _METRICS.histogram(f"pipeline.stage.{st.name}.window_seconds")
        depth = _METRICS.gauge(f"pipeline.stage.{st.name}.queue_rows")
        phase = 0
        rnd = -1
        while True:
            rnd += 1
            live = [w for w in self._live_workers(st)
                    if not ft.is_dead(st.name, w)]
            if not live:
                # every worker is dead: last-ditch live spare enrollment
                w = self._ft_enroll_spare(st, pool, ft)
                if w is None:
                    raise KeyDirectoryError(
                        f"every worker of stage {st.name!r} is dead and "
                        f"no spare could be admitted")
                live = [w]
            target = len(live) * window_chunks
            parts: List[SealedWindow] = []
            got = 0
            while got < target:
                win = next(upstream, None)
                if win is None:
                    break
                parts.append(win)
                got += len(win)
            if not parts:
                return
            # retain the sealed inputs (still under their reserved nonce
            # blocks) until this round's verdict sync is folded in
            ft.buffer.retain(st.name, rnd, parts)
            depth.set(got)
            tr.counter("queue_rows", got, track=st.name)
            live = [w for w in self._live_workers(st)
                    if not ft.is_dead(st.name, w)]
            L = len(live)
            d0 = _DISPATCHES.value
            t0 = time.perf_counter()
            dispatches = []          # (part idx, worker, row idxs, out, ok)
            flags = []               # aligned: per-share fault markers
            with tr.span("stage.dispatch", cat="dispatch", track=st.name,
                         rows=got, workers=L):
                for pi, win in enumerate(parts):
                    B = len(win)
                    assign = [(phase + j) % L for j in range(B)]
                    phase += B
                    for k in range(L):
                        idxs = [j for j in range(B) if assign[j] == k]
                        if not idxs:
                            continue
                        sub = win if len(idxs) == B else win.select(idxs)
                        w = live[k]
                        tampered = False
                        if secure and chaos is not None:
                            tf = chaos.tamper_for(st.name, rnd, w)
                            if tf is not None:
                                # corrupt the dispatch COPY only — the
                                # retained rows stay clean for replay
                                sub = chaos.apply_tamper(tf, sub)
                                tampered = True
                        share_id = ft.next_share_id()
                        w2, out, ok = self._ft_dispatch_share(
                            st, pool, ft, rnd, w, sub, share_id)
                        verdict_dropped = False
                        if secure and chaos is not None:
                            dv = chaos.drop_verdict_for(st.name, rnd, w)
                            verdict_dropped = dv is not None
                        dispatches.append((pi, w2, idxs, out, ok))
                        flags.append({"tampered": tampered,
                                      "verdict_dropped": verdict_dropped})
            verdicts = _sync_window(
                [d[3].words for d in dispatches],
                [(d[4], len(d[3])) for d in dispatches],
                tracer=tr, track=st.name)
            dt = time.perf_counter() - t0
            m.seconds += dt
            lat.observe(dt)
            m.windows += 1
            disp = _DISPATCHES.value - d0
            m.dispatches += disp
            tr.counter("windows_per_s", (1.0 / dt) if dt > 0 else 0.0,
                       track=st.name)
            # ---- per-row accounting + replay scheduling
            off = 0
            final = []               # dispatch tuples fed to the merge
            marks: List[np.ndarray] = []
            replays = []             # (part idx, worker, row js, reason)
            for di, (pi, w, idxs, out, _) in enumerate(dispatches):
                v = np.array(verdicts[off: off + len(idxs)], copy=True)
                off += len(idxs)
                if flags[di]["verdict_dropped"]:
                    # the host never saw this share's verdicts: every
                    # row is unverified -> replay the whole share
                    replays.append((pi, w, list(idxs), "verdict_dropped"))
                    continue
                for jj, alive_row in enumerate(v):
                    if alive_row:
                        m.chunks += 1
                        m.per_worker[w] += 1
                        m.bytes += int(parts[pi].n_words) * 4
                    else:
                        m.mac_failures += 1
                        pool[w].errors += 1
                        audit.record("mac_failure", stage=st.name,
                                     worker=self.worker_id(st.name, w),
                                     row=out.counters[jj],
                                     epoch=out.epochs[jj])
                final.append((pi, w, idxs, out, None))
                marks.append(v)
                failed_js = [j for jj, j in enumerate(idxs) if not v[jj]]
                if failed_js and secure and ft.policy.replay_mac_failures:
                    replays.append((pi, w, failed_js, "mac_failure"))
            if replays:
                rd = []
                for pi, w, row_js, reason in replays:
                    sub = parts[pi].select(row_js)
                    coords = self._ft_fresh_coords(len(sub))
                    wr = w if not ft.is_dead(st.name, w) else live[0]
                    out2, ok2 = self._ft_exec(st, pool[wr], sub, coords)
                    rd.append((pi, wr, row_js, out2, ok2))
                    ft.replays.inc()
                    audit.record("window_replayed", stage=st.name,
                                 worker=self.worker_id(st.name, wr),
                                 rows=len(row_js), reason=reason,
                                 round=rnd)
                rv = _sync_window([d[3].words for d in rd],
                                  [(d[4], len(d[3])) for d in rd],
                                  tracer=tr, track=st.name)
                roff = 0
                for (pi, _, row_js, reason), (pi2, wr, _, out2, _) \
                        in zip(replays, rd):
                    v2 = np.array(rv[roff: roff + len(row_js)], copy=True)
                    roff += len(row_js)
                    for jj, alive_row in enumerate(v2):
                        if alive_row:
                            m.chunks += 1
                            m.per_worker[wr] += 1
                            m.bytes += int(parts[pi].n_words) * 4
                        elif reason == "verdict_dropped":
                            # first time this row provably failed
                            m.mac_failures += 1
                            audit.record(
                                "mac_failure", stage=st.name,
                                worker=self.worker_id(st.name, wr),
                                row=out2.counters[jj],
                                epoch=out2.epochs[jj])
                        # a mac_failure replay that fails again was
                        # already audited on the original verdict
                    final.append((pi, wr, row_js, out2, None))
                    marks.append(v2)
            mon = self.monitor
            if mon.enabled:
                wrows: Dict[int, int] = {}
                for _, w, idxs, _, _ in final:
                    wrows[w] = wrows.get(w, 0) + len(idxs)
                mon.record_window(
                    st.name, rows=got,
                    ok_rows=int(sum(int(v.sum()) for v in marks)),
                    bytes=sum(len(p) * int(p.n_words) * 4 for p in parts),
                    seconds=dt, queue_rows=got, worker_rows=wrows,
                    min_epoch=min(min(p.epochs) for p in parts),
                    dispatches=disp)
            with tr.span("stage.merge", cat="pipeline", track=st.name,
                         windows=len(parts)):
                merged = list(self._merge_outputs(parts, final, marks))
            # the round's verdicts are folded in: release retained rows
            ft.buffer.ack(st.name, rnd)
            yield from merged

    def _ingress_stream(self, source: Iterable[jax.Array], mode: str,
                        rekey_every_n: Optional[int],
                        window: int) -> Iterator[SealedWindow]:
        """Seal source tensors window-at-a-time with a prefetch
        double-buffer: window N+1's (async) batched seal is dispatched
        BEFORE window N is handed downstream, so sealing overlaps
        downstream compute via JAX async dispatch.

        Each window reserves its nonce-counter blocks from the directory's
        managed per-edge counter (``EdgeHandle.reserve_window`` — the same
        discipline as ``secure_exchange``'s W^2 block), NOT a per-run
        enumerate: a second ``run()`` on the same pipeline (or a
        ``scale_stage`` continuation, which deliberately keeps the
        sessions) continues the count instead of resealing fresh plaintext
        under already-used (key, nonce) pairs.  ``rekey_every_n`` keeps
        its per-chunk cadence: a window is sealed as consecutive
        (epoch, shape)-uniform groups, each in one ``seal_many`` program,
        with ``advance_epoch`` firing between groups exactly where the
        per-chunk engine would have fired it — so rotation resets the
        managed counter, counters stay epoch-local, and chunks sealed just
        before a flip carry their epoch and drain under the old key.
        """
        it = iter(source)
        n_plain = 0
        tr = self.tracer
        mon = self.monitor
        buffered = _METRICS.gauge("pipeline.ingress.buffered_rows")
        prev: Optional[List[SealedWindow]] = None
        while True:
            xs = list(itertools.islice(it, window))
            if not xs:
                break
            d0 = _DISPATCHES.value
            t0 = time.perf_counter()
            with tr.span("ingress.seal", cat="dispatch", track="ingress",
                         rows=len(xs)):
                if mode == "plain":
                    cur = [plain_window(range(n_plain + j,
                                              n_plain + j + len(sub)), sub)
                           for j, sub in _shape_runs(xs)]
                    n_plain += len(xs)
                else:
                    cur = self._seal_ingress_window(xs, rekey_every_n)
            buffered.set(len(xs))
            disp = _DISPATCHES.value - d0
            self._ingress_windows_n += 1
            self._ingress_dispatches += disp
            if mon.enabled:
                mon.record_window(
                    "ingress", rows=len(xs),
                    bytes=sum(len(w) * int(w.n_words) * 4 for w in cur),
                    seconds=time.perf_counter() - t0, queue_rows=len(xs),
                    dispatches=disp)
            if prev is not None:
                yield from prev
            prev = cur
        if prev is not None:
            yield from prev
        buffered.set(0)

    def _seal_ingress_window(self, xs: List[jax.Array],
                             rekey: Optional[int]) -> List[SealedWindow]:
        """One sealed ingress window: (epoch, shape)-grouped batched seals
        over directory-reserved counter blocks."""
        h0 = self.keys[0]
        wins: List[SealedWindow] = []
        i = 0
        while i < len(xs):
            sess = self.directory.session(h0.edge)
            if rekey and sess.chunks >= rekey:
                self.tracer.instant("rekey", cat="security",
                                    track="ingress",
                                    epoch=self.directory.advance_epoch())
                sess = self.directory.session(h0.edge)
            room = len(xs) - i if not rekey else max(1, rekey - sess.chunks)
            group = xs[i:i + room]
            for _, sub in _shape_runs(group):
                base, epoch = h0.reserve_window(len(sub))
                wins.append(seal_tensors_window(
                    h0, range(base, base + len(sub)), sub, epoch=epoch))
            i += len(group)
        return wins

    def _clamp_window_for_rekey(self, wc: int, rekey_every_n: int) -> int:
        """Largest safe window factor for this rekey cadence.

        Chunks open under the epoch they were ingressed in, so the
        directory's ``epoch_history`` must cover the deepest possible
        in-flight lag.  The windowed engine buffers up to one window per
        stage, two ingress windows (the prefetch double-buffer), and one
        egress window; ``window_chunks=1`` dispatches to the per-chunk
        oracle engine, whose in-flight depth is only one window per stage
        (+1 being ingressed) — exactly the seed engine's bound, so a
        combination is rejected up front only if the seed engine would
        also have rejected it; otherwise the window is silently clamped
        to the safe size (down to the oracle if need be).
        """
        S = sum(max(1, s.workers) for s in self.stages)
        w0 = max(1, self.stages[0].workers) if self.stages else 1
        wl = max(1, self.stages[-1].workers) if self.stages else 1
        hist = self.directory.epoch_history

        seed_in_flight = S + 1              # the per-chunk oracle's depth
        seed_lag = -(-seed_in_flight // rekey_every_n) + 1
        if seed_lag > hist:
            raise ValueError(
                f"rekey_every_n={rekey_every_n} can rotate "
                f"{seed_lag} epochs while up to {seed_in_flight} chunks "
                f"are in flight, but KeyDirectory(epoch_history="
                f"{hist}) would prune keys "
                f"still needed to drain — raise epoch_history or "
                f"rekey_every_n")

        def lag(w: int) -> int:
            in_flight = (S + 2 * w0 + wl) * w + 1
            return -(-in_flight // rekey_every_n) + 1

        while wc > 1 and lag(wc) > hist:
            wc -= 1
        return wc

    def run(self, source: Iterable[jax.Array],
            on_result: Optional[Callable] = None,
            rekey_every_n: Optional[int] = None,
            window_chunks: Optional[int] = None,
            tracer=None, monitor=None,
            retry=None, chaos=None) -> Any:
        """Stream source tensors through all stages; returns the terminal
        reduce value (if the last stage reduces) or the last chunk.

        ``rekey_every_n``: rotate every edge session key after each N
        source chunks (KeyDirectory.advance_epoch) — mid-stream, without
        draining the pipeline.  Chunks open under the epoch they were
        ingressed in (windows straddling a flip use per-row keys), and the
        window factor is clamped so the directory's ``epoch_history``
        always covers the deepest in-flight lag (rejected up front if even
        the per-chunk engine could drain past history).

        ``window_chunks`` overrides the pipeline's window factor for this
        run; 1 is the per-chunk oracle engine.

        ``tracer``: a :class:`repro.obs.trace.Tracer` for this run only —
        per-window spans (ingress seal, per-worker open->op->seal,
        verdict syncs, merges, reduce folds) land on it, exportable as
        Chrome-trace JSON.  Defaults to the pipeline's own tracer
        (:data:`NULL_TRACER` unless one was passed at construction), so
        tracing is strictly opt-in and no-op-cheap when off.

        ``monitor``: a :class:`repro.obs.monitor.PipelineMonitor` for
        this run only — per-window sliding health (and any attached
        watchdogs) update live while the run streams.  Defaults to the
        pipeline's own monitor (:data:`NULL_MONITOR` unless one was
        passed at construction); a monitored run reads only host-side
        metadata, so output stays bit-identical to an unmonitored run.

        ``retry``: a :class:`repro.ft.retry.RetryPolicy` enabling
        per-share retry/backoff, failover, and replay-based recovery for
        this run only (requires the window engine, ``window_chunks>=2``).

        ``chaos``: a :class:`repro.ft.chaos.ChaosPlan` — seeded fault
        injection consulted at every engine hook point; implies FT with
        the default policy if ``retry`` is not also given.  The plan's
        ``enroll_fail`` faults are wired through the directory's
        admission interceptor for the duration of the run.
        """
        prev_tracer = self.tracer
        prev_monitor = self.monitor
        prev_retry = self.retry
        prev_chaos = self.chaos
        prev_icpt = self.directory.admission_interceptor
        if tracer is not None:
            self.tracer = tracer
        if monitor is not None:
            self.monitor = monitor
            monitor.attach(self)
        if retry is not None:
            self.retry = retry
        if chaos is not None:
            self.chaos = chaos
        if self.chaos is not None:
            self.directory.admission_interceptor = self.chaos.enroll_failure
        try:
            with self.tracer.span("pipeline.run", mode=self.secure.mode,
                                  stages=len(self.stages)):
                return self._run_impl(source, on_result, rekey_every_n,
                                      window_chunks)
        finally:
            self.tracer = prev_tracer
            self.monitor = prev_monitor
            self.retry = prev_retry
            self.chaos = prev_chaos
            self.directory.admission_interceptor = prev_icpt

    def _run_impl(self, source: Iterable[jax.Array],
                  on_result: Optional[Callable],
                  rekey_every_n: Optional[int],
                  window_chunks: Optional[int]) -> Any:
        mode = self.secure.mode
        wc = self.window_chunks if window_chunks is None \
            else max(1, int(window_chunks))
        if rekey_every_n and mode != "plain":
            wc = self._clamp_window_for_rekey(wc, rekey_every_n)
        ft = None
        if self.retry is not None or self.chaos is not None:
            from repro.ft.recovery import FTContext
            from repro.ft.retry import RetryPolicy
            ft = FTContext(policy=self.retry if self.retry is not None
                           else RetryPolicy(), chaos=self.chaos)
        self._last_ft = ft
        if wc == 1:
            if ft is not None:
                raise ValueError(
                    "fault tolerance (retry/chaos) needs the "
                    "window-vectorized engine (window_chunks >= 2); the "
                    "window factor resolved to 1 — if rekey_every_n "
                    "clamped it, build the pipeline with a "
                    "KeyDirectory(epoch_history=...) large enough for "
                    "the window/rekey combination")
            # the per-chunk oracle engine: scalar seal/open per chunk
            # with a blocking verdict sync per chunk (the seed engine,
            # kept as the degenerate case / bitwise oracle)
            return self._run_chunked(source, on_result, rekey_every_n)
        w0 = max(1, self.stages[0].workers) if self.stages else 1
        stream: Iterator[SealedWindow] = self._ingress_stream(
            source, mode, rekey_every_n, w0 * wc)

        # compose map/filter stages up to the terminal reduce (if any)
        reduce_idx = next((i for i, s in enumerate(self.stages)
                           if s.reduce_fn is not None), None)
        end = len(self.stages) if reduce_idx is None else reduce_idx
        for i in range(end):
            st = self.stages[i]
            pool = self._worker_pool(i, st)
            if ft is not None:
                stream = self._stage_stream_ft(stream, st, pool, wc, ft)
            else:
                stream = self._stage_stream(stream, st, pool, wc)
        sink_w = max(1, self.stages[end - 1].workers) if end else 1
        egress_rows = sink_w * wc

        if reduce_idx is not None:
            # terminal reduce: decrypt at the sink edge (trusted
            # subscriber), a window at a time, and fold in stream order;
            # the reduce swallows the stream.
            st = self.stages[reduce_idx]
            m = self.metrics[st.name]
            audit = self.directory.audit
            egress_lat = _METRICS.histogram("pipeline.egress.window_seconds")
            reduce_state: Any = None
            reduce_started = False
            for groups, verdicts, dt in self._egress_windows(
                    stream, mode, self.keys[reduce_idx], egress_rows):
                egress_lat.observe(dt)
                t0 = time.perf_counter()
                with self.tracer.span("reduce.fold", cat="pipeline",
                                      track="sink", rows=len(verdicts)):
                    off = 0
                    for win, vals in groups:
                        for j in range(len(win)):
                            if not verdicts[off + j]:
                                m.mac_failures += 1
                                audit.record(
                                    "mac_failure", stage=st.name,
                                    worker="io/sink",
                                    row=win.counters[j],
                                    epoch=win.epochs[j])
                                continue
                            if not reduce_started:
                                reduce_state = st.reduce_init
                                reduce_started = True
                            reduce_state = st.reduce_fn(reduce_state,
                                                        vals[j])
                            m.chunks += 1
                            m.bytes += int(win.n_words) * 4
                        off += len(win)
                m.seconds += dt + (time.perf_counter() - t0)
            return reduce_state if reduce_started else None

        final = None
        audit = self.directory.audit
        egress_lat = _METRICS.histogram("pipeline.egress.window_seconds")
        for groups, verdicts, dt in self._egress_windows(
                stream, mode, self.keys[len(self.stages)], egress_rows):
            egress_lat.observe(dt)
            off = 0
            for win, vals in groups:
                for j in range(len(win)):
                    final = vals[j]
                    if not verdicts[off + j]:
                        audit.record("mac_failure", stage="egress",
                                     worker="io/sink",
                                     row=win.counters[j],
                                     epoch=win.epochs[j])
                    elif on_result is not None:
                        on_result(vals[j])
                off += len(win)
        return final

    def _egress_windows(self, stream: Iterator[SealedWindow], mode: str,
                        key, window: int):
        """Open the terminal stream a window at a time (batched
        ``open_many`` per framing-uniform window, ONE deferred-verdict
        host sync per window).  Yields ([(window, opened tensor batch)],
        verdicts, seconds) — ``seconds`` spans dispatch through the
        blocking sync, so sink timing is honest."""
        parts: List[SealedWindow] = []
        got = 0
        for win in stream:
            parts.append(win)
            got += len(win)
            if got >= window:
                yield self._open_egress(parts, mode, key)
                parts, got = [], 0
        if parts:
            yield self._open_egress(parts, mode, key)

    def _open_egress(self, parts: List[SealedWindow], mode: str, key):
        d0 = _DISPATCHES.value
        t0 = time.perf_counter()
        groups = []
        specs = []
        with self.tracer.span("egress.open", cat="dispatch", track="sink",
                              rows=sum(len(w) for w in parts)):
            for win in parts:
                vals, ok = egress_window(mode, key, win)
                groups.append((win, vals))
                specs.append((ok, len(win)))
        verdicts = _sync_window([v for _, v in groups], specs,
                                tracer=self.tracer, track="sink")
        dt = time.perf_counter() - t0
        disp = _DISPATCHES.value - d0
        self._egress_windows_n += 1
        self._egress_dispatches += disp
        mon = self.monitor
        if mon.enabled:
            rows = sum(len(w) for w in parts)
            mon.record_window(
                "egress", rows=rows, ok_rows=int(verdicts.sum()),
                bytes=sum(len(w) * int(w.n_words) * 4 for w in parts),
                seconds=dt, dispatches=disp)
        return groups, verdicts, dt

    # ------------------------------------- per-chunk oracle (window_chunks=1)

    def _ingress_stream_chunked(self, source: Iterable[jax.Array],
                                mode: str, rekey_every_n: Optional[int]
                                ) -> Iterator[SealedChunk]:
        """Scalar per-chunk ingress (the oracle engine): one eager seal
        and one managed counter per chunk, rekey checked per chunk."""
        n_plain = 0
        for x in source:
            if mode == "plain":
                yield ingress(mode, None, n_plain, x)
                n_plain += 1
                continue
            h0 = self.keys[0]
            if rekey_every_n and \
                    self.directory.session(h0.edge).chunks >= rekey_every_n:
                self.tracer.instant("rekey", cat="security",
                                    track="ingress",
                                    epoch=self.directory.advance_epoch())
            yield ingress(mode, h0, h0.next_counter(), x)

    def _stage_stream_chunked(self, upstream: Iterator[SealedChunk],
                              st: Stage, pool: List[EnclaveExecutor]
                              ) -> Iterator[SealedChunk]:
        """The per-chunk oracle: scalar open->op->seal per chunk with a
        blocking ``bool(ok)`` host sync per chunk — round-robin dispatch
        over the pool, fair-queue merge of the worker sub-streams."""
        m = self.metrics[st.name]
        if len(m.per_worker) < len(pool):
            m.per_worker.extend([0] * (len(pool) - len(m.per_worker)))
        tr = self.tracer
        mon = self.monitor
        audit = self.directory.audit
        lat = _METRICS.histogram(f"pipeline.stage.{st.name}.window_seconds")
        while True:
            live = self._live_workers(st)
            window = list(itertools.islice(upstream, len(live)))
            if not window:
                return
            worker_outs: List[List[SealedChunk]] = []
            for k, queue in enumerate(R.round_robin(window, len(live))):
                w = live[k]
                outs: List[SealedChunk] = []
                for chunk in queue:
                    d0 = _DISPATCHES.value
                    t0 = time.perf_counter()
                    with tr.span("stage.chunk", cat="dispatch",
                                 track=f"{st.name}/w{w}",
                                 row=chunk.counter):
                        if st.fn is not None:
                            out = pool[w].run(st.fn, chunk)
                        else:
                            out = pool[w].run_static(st.op, st.const, chunk)
                    if pool[w].mode != "plain":
                        _HOST_SYNCS.inc()      # the scalar bool(ok) sync
                    dt = time.perf_counter() - t0
                    m.seconds += dt
                    lat.observe(dt)            # the oracle's window IS a chunk
                    m.windows += 1
                    disp = _DISPATCHES.value - d0
                    m.dispatches += disp
                    if mon.enabled:
                        mon.record_window(
                            st.name, rows=1,
                            ok_rows=0 if out is None else 1,
                            bytes=0 if out is None
                            else int(chunk.n_words) * 4,
                            seconds=dt, queue_rows=len(window),
                            worker_rows={w: 1}, min_epoch=chunk.epoch,
                            dispatches=disp)
                    if out is None:
                        m.mac_failures += 1
                        audit.record("mac_failure", stage=st.name,
                                     worker=self.worker_id(st.name, w),
                                     row=chunk.counter, epoch=chunk.epoch)
                        continue
                    m.chunks += 1
                    m.per_worker[w] += 1
                    m.bytes += int(chunk.n_words) * 4
                    outs.append(out)
                worker_outs.append(outs)
            yield from R.fair_queue(worker_outs)

    def _run_chunked(self, source: Iterable[jax.Array],
                     on_result: Optional[Callable],
                     rekey_every_n: Optional[int]) -> Any:
        """The original streaming engine, chunk by chunk (the
        ``window_chunks=1`` degenerate case)."""
        mode = self.secure.mode
        audit = self.directory.audit
        stream: Iterator[SealedChunk] = self._ingress_stream_chunked(
            source, mode, rekey_every_n)
        reduce_idx = next((i for i, s in enumerate(self.stages)
                           if s.reduce_fn is not None), None)
        end = len(self.stages) if reduce_idx is None else reduce_idx
        for i in range(end):
            st = self.stages[i]
            stream = self._stage_stream_chunked(stream, st,
                                                self._worker_pool(i, st))

        if reduce_idx is not None:
            st = self.stages[reduce_idx]
            m = self.metrics[st.name]
            reduce_state: Any = None
            reduce_started = False
            for chunk in stream:
                t0 = time.perf_counter()
                val, ok = egress(mode, self.keys[reduce_idx], chunk)
                if mode != "plain":
                    _HOST_SYNCS.inc()
                if not bool(ok):
                    m.mac_failures += 1
                    audit.record("mac_failure", stage=st.name,
                                 worker="io/sink", row=chunk.counter,
                                 epoch=chunk.epoch)
                    continue
                if not reduce_started:
                    reduce_state = st.reduce_init
                    reduce_started = True
                reduce_state = st.reduce_fn(reduce_state, val)
                m.chunks += 1
                m.bytes += int(chunk.n_words) * 4
                m.seconds += time.perf_counter() - t0
            return reduce_state if reduce_started else None

        final = None
        for chunk in stream:
            result, ok = egress(mode, self.keys[len(self.stages)], chunk)
            if mode != "plain":
                _HOST_SYNCS.inc()
            final = result
            if not bool(ok):
                audit.record("mac_failure", stage="egress",
                             worker="io/sink", row=chunk.counter,
                             epoch=chunk.epoch)
            elif on_result is not None:
                on_result(result)
        return final

    # ------------------------------------------------------------- elastic

    def scale_stage(self, name: str, workers: int) -> "Pipeline":
        """Elastic scaling: change a stage's worker count (paper §5.5).

        The KeyDirectory (sessions, epoch, revocations), the seed, AND the
        accumulated StageMetrics carry forward, so throughput/error
        reports stay continuous across rescale events and the stream is
        not re-keyed (the paper's live-reconfiguration experiment reports
        one unbroken trajectory).  New workers are admitted only if their
        quote verifies against the stage's measurement; revoked ids stay
        quarantined — scale-up cannot resurrect an evicted worker.
        """
        stages = [
            Stage(**{**s.__dict__, "workers": workers}) if s.name == name
            else s for s in self.stages
        ]
        p = Pipeline(stages, self.secure, seed=self.seed,
                     directory=self.directory,
                     window_chunks=self.window_chunks,
                     fusion=self.fusion,
                     tracer=None if self.tracer is NULL_TRACER
                     else self.tracer,
                     monitor=None if self.monitor is NULL_MONITOR
                     else self.monitor)
        p._evicted_logged = self._evicted_logged
        # ingress/egress hop accounting continues across the rescale,
        # like the per-stage metrics below
        p._ingress_windows_n = self._ingress_windows_n
        p._ingress_dispatches = self._ingress_dispatches
        p._egress_windows_n = self._egress_windows_n
        p._egress_dispatches = self._egress_dispatches
        for sname, m in self.metrics.items():
            pw = list(m.per_worker)
            if sname == name and len(pw) < workers:
                pw.extend([0] * (workers - len(pw)))
            p.metrics[sname] = dataclasses.replace(m, per_worker=pw)
        return p

    def report(self) -> Dict[str, Dict[str, Any]]:
        """Per-stage metrics dict (chunks, bytes, seconds, MB/s, MAC
        failures, per-worker counts).  Stages the DSL compiler merged
        carry a ``fused_from`` list, and a top-level ``"fusion"`` entry
        logs every fusion decision (taken or declined) — both absent for
        hand-built pipelines, whose report shape is unchanged."""
        fused_from = self.fusion.get("fused_from", {})
        out: Dict[str, Dict[str, Any]] = {
            name: {"chunks": m.chunks, "bytes": m.bytes,
                   "seconds": round(m.seconds, 4),
                   # None = nothing measured yet (distinct from a true 0.0)
                   "throughput_mbps": None if m.throughput_mbps is None
                   else round(m.throughput_mbps, 2),
                   "mac_failures": m.mac_failures,
                   "mac_failure_rate": None if m.mac_failure_rate is None
                   else round(m.mac_failure_rate, 4),
                   "per_worker": list(m.per_worker),
                   "windows": m.windows,
                   "dispatches": m.dispatches,
                   "dispatches_per_window":
                   None if m.dispatches_per_window is None
                   else round(m.dispatches_per_window, 4),
                   **({"fused_from": list(fused_from[name])}
                      if name in fused_from else {})}
            for name, m in self.metrics.items()
        }
        if self.fusion.get("decisions"):
            out["fusion"] = {"decisions": list(self.fusion["decisions"])}
        out["audit"] = self.directory.audit.summary()
        out["dispatch"] = {
            "total": self._ingress_dispatches + self._egress_dispatches
            + sum(m.dispatches for m in self.metrics.values()),
            "ingress": {"windows": self._ingress_windows_n,
                        "dispatches": self._ingress_dispatches},
            "egress": {"windows": self._egress_windows_n,
                       "dispatches": self._egress_dispatches},
        }
        return out
