"""Pipeline builder: stages + routers -> an executable secure dataflow.

Mirrors the paper's Compose description (Listing 1): a pipeline is a list
of named stages, each with an operator, a worker count, and a placement
("sgx" workers are the ones whose operator runs under the enclave
executor).  Routers between stages apply fair-queue (in) / round-robin
(out) chunk scheduling — repro.core.router.

Execution is streaming: chunks flow stage to stage; each stage re-keys the
chunk for its outbound edge (per-stage session keys, repro.crypto.keys).
Per-stage counters, byte totals, and MAC failures feed the benchmarks
(paper Fig. 6/7/8).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SecureStreamConfig
from repro.core import router as R
from repro.core.enclave import (EnclaveExecutor, SealedChunk, egress,
                                ingress)
from repro.crypto.keys import StageKey, derive_stage_key, root_key_from_seed


@dataclass
class Stage:
    name: str
    op: str                              # static registry op name, or "custom"
    const: float = 0.0
    fn: Optional[Callable] = None        # custom fn (plain/encrypted only)
    workers: int = 1
    sgx: bool = True                     # paper: constraint:type==sgx
    reduce_fn: Optional[Callable] = None # terminal reduce (runs at egress)
    reduce_init: Any = None


@dataclass
class StageMetrics:
    chunks: int = 0
    bytes: int = 0
    seconds: float = 0.0
    mac_failures: int = 0
    # chunks handled per worker of the stage (round-robin fan-out accounting;
    # survives rescaling — scale_stage pads/keeps this list).
    per_worker: List[int] = field(default_factory=list)

    @property
    def throughput_mbps(self) -> float:
        return (self.bytes / 1e6) / self.seconds if self.seconds else 0.0


class Pipeline:
    def __init__(self, stages: Sequence[Stage],
                 secure: SecureStreamConfig = SecureStreamConfig(),
                 seed: int = 0):
        self.stages = list(stages)
        self.secure = secure
        self.seed = seed
        root = root_key_from_seed(seed)
        # edge i connects stage i-1 -> i; key per edge (+ source and sink).
        self.keys: List[StageKey] = [
            derive_stage_key(root, f"edge{i}", i)
            for i in range(len(self.stages) + 1)
        ]
        self.metrics: Dict[str, StageMetrics] = {
            s.name: StageMetrics() for s in self.stages}

    # ------------------------------------------------------------------ run

    def _worker_pool(self, i: int, st: Stage) -> List[EnclaveExecutor]:
        """One executor per worker of stage i (paper: W identical workers
        behind the stage's inbound router, all sharing the edge keys)."""
        mode = self.secure.mode
        st_mode = mode if st.sgx else ("plain" if mode == "plain"
                                       else "encrypted")
        return [EnclaveExecutor(st_mode, self.keys[i], self.keys[i + 1])
                for _ in range(max(1, st.workers))]

    def _stage_stream(self, upstream: Iterator[SealedChunk], st: Stage,
                      pool: List[EnclaveExecutor]) -> Iterator[SealedChunk]:
        """Fan a chunk stream across the stage's workers.

        Outbound edge: round-robin dispatch (paper's Push socket) over the
        worker pool; inbound edge: fair-queue merge (Pull socket) of the
        worker sub-streams — both via repro.core.router, so the rr->fq
        composition preserves stream order.  Chunks that fail their MAC
        check are dropped (reactive on_error semantics) and counted.
        """
        W = len(pool)
        m = self.metrics[st.name]
        if len(m.per_worker) < W:
            m.per_worker.extend([0] * (W - len(m.per_worker)))
        while True:
            window = list(itertools.islice(upstream, W))
            if not window:
                return
            worker_outs: List[List[SealedChunk]] = []
            for w, queue in enumerate(R.round_robin(window, W)):
                outs: List[SealedChunk] = []
                for chunk in queue:
                    t0 = time.perf_counter()
                    if st.fn is not None:
                        out = pool[w].run(st.fn, chunk)
                    else:
                        out = pool[w].run_static(st.op, st.const, chunk)
                    m.seconds += time.perf_counter() - t0
                    if out is None:
                        m.mac_failures += 1
                        continue
                    m.chunks += 1
                    m.per_worker[w] += 1
                    m.bytes += int(chunk.n_words) * 4
                    outs.append(out)
                worker_outs.append(outs)
            yield from R.fair_queue(worker_outs)

    def run(self, source: Iterable[jax.Array],
            on_result: Optional[Callable] = None) -> Any:
        """Stream source tensors through all stages; returns the terminal
        reduce value (if the last stage reduces) or the last chunk."""
        mode = self.secure.mode
        stream: Iterator[SealedChunk] = (
            ingress(mode, self.keys[0], counter, x)
            for counter, x in enumerate(source))

        # compose map/filter stages up to the terminal reduce (if any)
        reduce_idx = next((i for i, s in enumerate(self.stages)
                           if s.reduce_fn is not None), None)
        end = len(self.stages) if reduce_idx is None else reduce_idx
        for i in range(end):
            st = self.stages[i]
            stream = self._stage_stream(stream, st, self._worker_pool(i, st))

        if reduce_idx is not None:
            # terminal reduce: decrypt at the sink edge (trusted subscriber)
            # and fold; the reduce swallows the stream.
            st = self.stages[reduce_idx]
            m = self.metrics[st.name]
            reduce_state: Any = None
            reduce_started = False
            for chunk in stream:
                t0 = time.perf_counter()
                val, ok = egress(mode, self.keys[reduce_idx], chunk)
                if not bool(ok):
                    m.mac_failures += 1
                    continue
                if not reduce_started:
                    reduce_state = st.reduce_init
                    reduce_started = True
                reduce_state = st.reduce_fn(reduce_state, val)
                m.chunks += 1
                m.bytes += int(chunk.n_words) * 4
                m.seconds += time.perf_counter() - t0
            return reduce_state if reduce_started else None

        final = None
        for chunk in stream:
            result, ok = egress(mode, self.keys[len(self.stages)], chunk)
            final = result
            if on_result is not None and bool(ok):
                on_result(result)
        return final

    # ------------------------------------------------------------- elastic

    def scale_stage(self, name: str, workers: int) -> "Pipeline":
        """Elastic scaling: change a stage's worker count (paper §5.5).

        Session keys, the key-derivation seed, AND the accumulated
        StageMetrics carry forward, so throughput/error reports stay
        continuous across rescale events (the paper's live-reconfiguration
        experiment reports one unbroken trajectory).
        """
        stages = [
            Stage(**{**s.__dict__, "workers": workers}) if s.name == name
            else s for s in self.stages
        ]
        p = Pipeline(stages, self.secure, seed=self.seed)
        p.keys = self.keys
        for sname, m in self.metrics.items():
            pw = list(m.per_worker)
            if sname == name and len(pw) < workers:
                pw.extend([0] * (workers - len(pw)))
            p.metrics[sname] = dataclasses.replace(m, per_worker=pw)
        return p

    def report(self) -> Dict[str, Dict[str, Any]]:
        return {
            name: {"chunks": m.chunks, "bytes": m.bytes,
                   "seconds": round(m.seconds, 4),
                   "throughput_mbps": round(m.throughput_mbps, 2),
                   "mac_failures": m.mac_failures,
                   "per_worker": list(m.per_worker)}
            for name, m in self.metrics.items()
        }
