"""Pipeline builder: stages + routers -> an executable secure dataflow.

Mirrors the paper's Compose description (Listing 1): a pipeline is a list
of named stages, each with an operator, a worker count, and a placement
("sgx" workers are the ones whose operator runs under the enclave
executor).  Routers between stages apply fair-queue (in) / round-robin
(out) chunk scheduling — repro.core.router.

Execution is streaming: chunks flow stage to stage; each stage re-keys the
chunk for its outbound edge (per-stage session keys, repro.crypto.keys).
Per-stage counters, byte totals, and MAC failures feed the benchmarks
(paper Fig. 6/7/8).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SecureStreamConfig
from repro.core import router as R
from repro.core.enclave import (EnclaveExecutor, SealedChunk, egress,
                                ingress)
from repro.crypto.keys import StageKey, derive_stage_key, root_key_from_seed


@dataclass
class Stage:
    name: str
    op: str                              # static registry op name, or "custom"
    const: float = 0.0
    fn: Optional[Callable] = None        # custom fn (plain/encrypted only)
    workers: int = 1
    sgx: bool = True                     # paper: constraint:type==sgx
    reduce_fn: Optional[Callable] = None # terminal reduce (runs at egress)
    reduce_init: Any = None


@dataclass
class StageMetrics:
    chunks: int = 0
    bytes: int = 0
    seconds: float = 0.0
    mac_failures: int = 0

    @property
    def throughput_mbps(self) -> float:
        return (self.bytes / 1e6) / self.seconds if self.seconds else 0.0


class Pipeline:
    def __init__(self, stages: Sequence[Stage],
                 secure: SecureStreamConfig = SecureStreamConfig(),
                 seed: int = 0):
        self.stages = list(stages)
        self.secure = secure
        root = root_key_from_seed(seed)
        # edge i connects stage i-1 -> i; key per edge (+ source and sink).
        self.keys: List[StageKey] = [
            derive_stage_key(root, f"edge{i}", i)
            for i in range(len(self.stages) + 1)
        ]
        self.metrics: Dict[str, StageMetrics] = {
            s.name: StageMetrics() for s in self.stages}

    # ------------------------------------------------------------------ run

    def run(self, source: Iterable[jax.Array],
            on_result: Optional[Callable] = None) -> Any:
        """Stream source tensors through all stages; returns the terminal
        reduce value (if the last stage reduces) or the last chunk."""
        mode = self.secure.mode
        execs = []
        for i, st in enumerate(self.stages):
            st_mode = mode if st.sgx else ("plain" if mode == "plain"
                                           else "encrypted")
            execs.append(EnclaveExecutor(st_mode, self.keys[i],
                                         self.keys[i + 1]))

        reduce_state: Any = None
        reduce_started = False
        final = None

        for counter, x in enumerate(source):
            chunk = ingress(mode, self.keys[0], counter, x)
            alive = True
            for i, (st, ex) in enumerate(zip(self.stages, execs)):
                t0 = time.perf_counter()
                m = self.metrics[st.name]
                if st.reduce_fn is not None:
                    # terminal reduce: decrypt at the sink edge (trusted
                    # subscriber) and fold.
                    val, ok = egress(ex.mode if ex.mode != "plain" else "plain",
                                     self.keys[i], chunk)
                    if not bool(ok):
                        m.mac_failures += 1
                        alive = False
                        break
                    if not reduce_started:
                        reduce_state = st.reduce_init
                        reduce_started = True
                    reduce_state = st.reduce_fn(reduce_state, val)
                    m.chunks += 1
                    m.bytes += int(chunk.n_words) * 4
                    m.seconds += time.perf_counter() - t0
                    alive = False  # reduce swallows the chunk
                    break
                if st.fn is not None:
                    out = ex.run(st.fn, chunk)
                else:
                    out = ex.run_static(st.op, st.const, chunk)
                m.seconds += time.perf_counter() - t0
                if out is None:
                    m.mac_failures += 1
                    alive = False
                    break
                m.chunks += 1
                m.bytes += int(chunk.n_words) * 4
                chunk = out
            if alive:
                result, ok = egress(mode, self.keys[len(self.stages)], chunk)
                final = result
                if on_result is not None and bool(ok):
                    on_result(result)

        if reduce_started:
            return reduce_state
        return final

    # ------------------------------------------------------------- elastic

    def scale_stage(self, name: str, workers: int) -> "Pipeline":
        """Elastic scaling: change a stage's worker count (paper §5.5)."""
        stages = [
            Stage(**{**s.__dict__, "workers": workers}) if s.name == name
            else s for s in self.stages
        ]
        p = Pipeline(stages, self.secure)
        p.keys = self.keys
        return p

    def report(self) -> Dict[str, Dict[str, float]]:
        return {
            name: {"chunks": m.chunks, "bytes": m.bytes,
                   "seconds": round(m.seconds, 4),
                   "throughput_mbps": round(m.throughput_mbps, 2),
                   "mac_failures": m.mac_failures}
            for name, m in self.metrics.items()
        }
