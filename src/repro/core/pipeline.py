"""Pipeline builder: stages + routers -> an executable secure dataflow.

Mirrors the paper's Compose description (Listing 1): a pipeline is a list
of named stages, each with an operator, a worker count, and a placement
("sgx" workers are the ones whose operator runs under the enclave
executor).  Routers between stages apply fair-queue (in) / round-robin
(out) chunk scheduling — repro.core.router.

Execution is streaming: chunks flow stage to stage; each stage re-keys the
chunk for its outbound edge.  Per-edge session keys come from a
``repro.attest.KeyDirectory``: every stage worker is measured
(repro.attest.measure), enrolled, and admitted only if its quote verifies,
and edge keys are established by the attested handshake — the trust
bootstrap the paper assumes pre-done.  ``run(rekey_every_n=...)`` rotates
every edge key mid-stream (epoch ratchet; old-epoch chunks drain, new
chunks seal under the new epoch), and ``KeyDirectory.revoke`` evicts a
worker live — subsequent windows skip it.  Per-stage counters, byte
totals, and MAC failures feed the benchmarks (paper Fig. 6/7/8).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.attest.directory import (EdgeHandle, KeyDirectory,
                                    KeyDirectoryError)
from repro.attest.measure import IO_ENDPOINT, measure_stage
from repro.configs.base import SecureStreamConfig
from repro.core import router as R
from repro.core.enclave import (EnclaveExecutor, SealedChunk, egress,
                                ingress)


@dataclass
class Stage:
    name: str
    op: str                              # static registry op name, or "custom"
    const: float = 0.0
    fn: Optional[Callable] = None        # custom fn (plain/encrypted only)
    workers: int = 1
    sgx: bool = True                     # paper: constraint:type==sgx
    reduce_fn: Optional[Callable] = None # terminal reduce (runs at egress)
    reduce_init: Any = None


@dataclass
class StageMetrics:
    chunks: int = 0
    bytes: int = 0
    seconds: float = 0.0
    mac_failures: int = 0
    # chunks handled per worker of the stage (round-robin fan-out accounting;
    # survives rescaling — scale_stage pads/keeps this list).
    per_worker: List[int] = field(default_factory=list)

    @property
    def throughput_mbps(self) -> float:
        return (self.bytes / 1e6) / self.seconds if self.seconds else 0.0


class Pipeline:
    def __init__(self, stages: Sequence[Stage],
                 secure: SecureStreamConfig = SecureStreamConfig(),
                 seed: int = 0,
                 directory: Optional[KeyDirectory] = None):
        self.stages = list(stages)
        self.secure = secure
        self.seed = seed
        # The directory owns every session key; passing one in (scale_stage,
        # shared trust domain) carries sessions, epoch, and revocations over.
        self.directory = directory if directory is not None \
            else KeyDirectory(seed=seed)
        self._setup_attestation()
        # edge i connects stage i-1 -> i (+ source and sink); handles pull
        # the live epoch key from the directory on every seal/open.  Plain
        # mode never touches a key, so it skips the edge handshakes
        # entirely (workers are still measured and admitted).
        self.keys: List[Optional[EdgeHandle]] = [
            self.directory.handle(f"edge{i}")
            for i in range(len(self.stages) + 1)
        ] if secure.mode != "plain" else [None] * (len(self.stages) + 1)
        self.metrics: Dict[str, StageMetrics] = {
            s.name: StageMetrics() for s in self.stages}

    # -------------------------------------------------------- attestation

    @staticmethod
    def worker_id(stage_name: str, w: int) -> str:
        return f"{stage_name}/w{w}"

    def _setup_attestation(self) -> None:
        """Measure + enroll every endpoint and worker, verify quotes, and
        establish per-edge session keys via the attested handshake.

        Revoked worker ids stay quarantined (they are neither re-enrolled
        nor admitted — scale_stage cannot resurrect them); existing edge
        sessions are reused so a rescale does not re-key the stream.
        """
        d = self.directory
        S = len(self.stages)
        endpoints = ["io/source"] + [f"stage/{s.name}" for s in self.stages] \
            + ["io/sink"]
        d.enroll("io/source", IO_ENDPOINT, allow=True)
        d.enroll("io/sink", IO_ENDPOINT, allow=True)
        for st in self.stages:
            m = measure_stage(op=st.op, const=st.const, fn=st.fn, sgx=st.sgx)
            d.policy.allow(m)
            d.enroll(f"stage/{st.name}", m)
            for w in range(max(1, st.workers)):
                wid = self.worker_id(st.name, w)
                if d.policy.is_revoked(wid):
                    continue                     # stays evicted
                d.enroll(wid, m)
                d.admit(wid)                     # raises unless quote verifies
        if self.secure.mode == "plain":
            return                               # no keys -> no handshakes
        for i in range(S + 1):
            if not d.has_session(f"edge{i}"):
                d.establish(f"edge{i}", endpoints[i], endpoints[i + 1],
                            stage_id=i)

    def _live_workers(self, st: Stage) -> List[int]:
        """Worker indices still dispatchable.

        Full quote admission (sign + verify) happened at build/rescale;
        the only bit that can flip mid-stream is revocation, so the
        per-window check is a set lookup, not a re-attestation.
        """
        live = [w for w in range(max(1, st.workers))
                if not self.directory.policy.is_revoked(
                    self.worker_id(st.name, w))]
        if not live:
            # deliberately NOT RevokedWorkerError: a stage name is not a
            # worker id, and the ft supervisor revokes e.worker_id
            raise KeyDirectoryError(
                f"every worker of stage {st.name!r} is revoked or "
                f"inadmissible — nothing can process the edge")
        return live

    # ------------------------------------------------------------------ run

    def _worker_pool(self, i: int, st: Stage) -> List[EnclaveExecutor]:
        """One executor per worker of stage i (paper: W identical workers
        behind the stage's inbound router, all sharing the edge keys)."""
        mode = self.secure.mode
        st_mode = mode if st.sgx else ("plain" if mode == "plain"
                                       else "encrypted")
        return [EnclaveExecutor(st_mode, self.keys[i], self.keys[i + 1])
                for _ in range(max(1, st.workers))]

    def _stage_stream(self, upstream: Iterator[SealedChunk], st: Stage,
                      pool: List[EnclaveExecutor]) -> Iterator[SealedChunk]:
        """Fan a chunk stream across the stage's workers.

        Outbound edge: round-robin dispatch (paper's Push socket) over the
        worker pool; inbound edge: fair-queue merge (Pull socket) of the
        worker sub-streams — both via repro.core.router, so the rr->fq
        composition preserves stream order.  Chunks that fail their MAC
        check are dropped (reactive on_error semantics) and counted.
        Revocation is re-checked per window, so a worker revoked
        mid-stream stops receiving chunks at the next dispatch.
        """
        m = self.metrics[st.name]
        if len(m.per_worker) < len(pool):
            m.per_worker.extend([0] * (len(pool) - len(m.per_worker)))
        while True:
            live = self._live_workers(st)
            window = list(itertools.islice(upstream, len(live)))
            if not window:
                return
            worker_outs: List[List[SealedChunk]] = []
            for k, queue in enumerate(R.round_robin(window, len(live))):
                w = live[k]
                outs: List[SealedChunk] = []
                for chunk in queue:
                    t0 = time.perf_counter()
                    if st.fn is not None:
                        out = pool[w].run(st.fn, chunk)
                    else:
                        out = pool[w].run_static(st.op, st.const, chunk)
                    m.seconds += time.perf_counter() - t0
                    if out is None:
                        m.mac_failures += 1
                        continue
                    m.chunks += 1
                    m.per_worker[w] += 1
                    m.bytes += int(chunk.n_words) * 4
                    outs.append(out)
                worker_outs.append(outs)
            yield from R.fair_queue(worker_outs)

    def _ingress_stream(self, source: Iterable[jax.Array], mode: str,
                        rekey_every_n: Optional[int]
                        ) -> Iterator[SealedChunk]:
        """Seal source tensors; rotate every edge key each N chunks.

        Ingress counters are allocated from the directory's managed
        per-edge counter, NOT a per-run enumerate: a second ``run()`` on
        the same pipeline (or a ``scale_stage`` continuation, which
        deliberately keeps the sessions) continues the count instead of
        resealing fresh plaintext under already-used (key, nonce) pairs.
        Rotation resets the managed counter, keeping counters epoch-local
        (the nonce-exhaustion guard in repro.crypto.keys never trips on a
        rotating stream); chunks sealed just before a flip carry their
        epoch and drain under the old key while new chunks seal under the
        new one.
        """
        n_plain = 0
        for x in source:
            if mode == "plain":
                yield ingress(mode, None, n_plain, x)
                n_plain += 1
                continue
            h0 = self.keys[0]
            if rekey_every_n and \
                    self.directory.session(h0.edge).chunks >= rekey_every_n:
                self.directory.advance_epoch()
            yield ingress(mode, h0, h0.next_counter(), x)

    def run(self, source: Iterable[jax.Array],
            on_result: Optional[Callable] = None,
            rekey_every_n: Optional[int] = None) -> Any:
        """Stream source tensors through all stages; returns the terminal
        reduce value (if the last stage reduces) or the last chunk.

        ``rekey_every_n``: rotate every edge session key after each N
        source chunks (KeyDirectory.advance_epoch) — mid-stream, without
        draining the pipeline.  Chunks open under the epoch they were
        ingressed in, so the directory's ``epoch_history`` must cover the
        deepest possible in-flight lag (checked up front: every stage
        window can buffer up to its worker count of chunks).
        """
        mode = self.secure.mode
        if rekey_every_n and mode != "plain":
            # worst-case chunks in flight = one window per stage (+1 being
            # ingressed); an old chunk may lag that many rotations behind
            in_flight = sum(max(1, s.workers) for s in self.stages) + 1
            lag = -(-in_flight // rekey_every_n) + 1   # ceil + safety
            if lag > self.directory.epoch_history:
                raise ValueError(
                    f"rekey_every_n={rekey_every_n} can rotate "
                    f"{lag} epochs while up to {in_flight} chunks are in "
                    f"flight, but KeyDirectory(epoch_history="
                    f"{self.directory.epoch_history}) would prune keys "
                    f"still needed to drain — raise epoch_history or "
                    f"rekey_every_n")
        stream: Iterator[SealedChunk] = self._ingress_stream(
            source, mode, rekey_every_n)

        # compose map/filter stages up to the terminal reduce (if any)
        reduce_idx = next((i for i, s in enumerate(self.stages)
                           if s.reduce_fn is not None), None)
        end = len(self.stages) if reduce_idx is None else reduce_idx
        for i in range(end):
            st = self.stages[i]
            stream = self._stage_stream(stream, st, self._worker_pool(i, st))

        if reduce_idx is not None:
            # terminal reduce: decrypt at the sink edge (trusted subscriber)
            # and fold; the reduce swallows the stream.
            st = self.stages[reduce_idx]
            m = self.metrics[st.name]
            reduce_state: Any = None
            reduce_started = False
            for chunk in stream:
                t0 = time.perf_counter()
                val, ok = egress(mode, self.keys[reduce_idx], chunk)
                if not bool(ok):
                    m.mac_failures += 1
                    continue
                if not reduce_started:
                    reduce_state = st.reduce_init
                    reduce_started = True
                reduce_state = st.reduce_fn(reduce_state, val)
                m.chunks += 1
                m.bytes += int(chunk.n_words) * 4
                m.seconds += time.perf_counter() - t0
            return reduce_state if reduce_started else None

        final = None
        for chunk in stream:
            result, ok = egress(mode, self.keys[len(self.stages)], chunk)
            final = result
            if on_result is not None and bool(ok):
                on_result(result)
        return final

    # ------------------------------------------------------------- elastic

    def scale_stage(self, name: str, workers: int) -> "Pipeline":
        """Elastic scaling: change a stage's worker count (paper §5.5).

        The KeyDirectory (sessions, epoch, revocations), the seed, AND the
        accumulated StageMetrics carry forward, so throughput/error
        reports stay continuous across rescale events and the stream is
        not re-keyed (the paper's live-reconfiguration experiment reports
        one unbroken trajectory).  New workers are admitted only if their
        quote verifies against the stage's measurement; revoked ids stay
        quarantined — scale-up cannot resurrect an evicted worker.
        """
        stages = [
            Stage(**{**s.__dict__, "workers": workers}) if s.name == name
            else s for s in self.stages
        ]
        p = Pipeline(stages, self.secure, seed=self.seed,
                     directory=self.directory)
        for sname, m in self.metrics.items():
            pw = list(m.per_worker)
            if sname == name and len(pw) < workers:
                pw.extend([0] * (workers - len(pw)))
            p.metrics[sname] = dataclasses.replace(m, per_worker=pw)
        return p

    def report(self) -> Dict[str, Dict[str, Any]]:
        return {
            name: {"chunks": m.chunks, "bytes": m.bytes,
                   "seconds": round(m.seconds, 4),
                   "throughput_mbps": round(m.throughput_mbps, 2),
                   "mac_failures": m.mac_failures,
                   "per_worker": list(m.per_worker)}
            for name, m in self.metrics.items()
        }
