"""Router components: the paper's ZeroMQ push/pull brokers, TPU-native.

A router connects two stages.  Inbound it *fair-queues* (paper: Pull socket
with fair-queuing over anonymous upstream workers); outbound it dispatches
to downstream workers *round-robin* (Push socket).  Here workers are mesh
shards, so the policies become deterministic resharding schedules:

* ``round_robin``  — chunk i of the stream goes to worker i mod W;
* ``fair_queue``   — merge W worker sub-streams, one chunk each in turn;
* ``shuffle``      — all-to-all over a key (the map->reduce boundary);
* ``keyed``        — consistent routing by key hash (stateful reducers).

On a real mesh the shuffle/keyed policies lower onto ``lax.all_to_all``
via shard_map (`shuffle_sharded`); the chunk-level policies drive the
pipeline scheduler (repro.core.pipeline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterable, Iterator, List, Sequence

import jax
import jax.numpy as jnp

Chunk = Any


@dataclass(frozen=True)
class RouterPolicy:
    kind: str                     # round_robin | fair_queue | shuffle | keyed
    num_keys: int = 0


def round_robin(chunks: Iterable[Chunk], num_workers: int) -> List[List[Chunk]]:
    """Outbound dispatch: chunk i -> worker i mod W (paper's Push socket)."""
    queues: List[List[Chunk]] = [[] for _ in range(num_workers)]
    for i, c in enumerate(chunks):
        queues[i % num_workers].append(c)
    return queues


def fair_queue(worker_streams: Sequence[Iterable[Chunk]]) -> Iterator[Chunk]:
    """Inbound merge: one chunk from each live worker in turn (Pull socket)."""
    iters = [iter(s) for s in worker_streams]
    live = list(range(len(iters)))
    while live:
        nxt = []
        for w in live:
            try:
                yield next(iters[w])
                nxt.append(w)
            except StopIteration:
                pass
        live = nxt


def shuffle_by_key(chunk: jax.Array, keys: jax.Array, num_keys: int,
                   mask=None):
    """Group rows of a chunk by key (dense): returns (num_keys, cap, ...)
    buckets + per-bucket counts. The dataflow equivalent of a keyed shuffle."""
    n = keys.shape[0]
    cap = n  # worst case: all rows one key (dense bound)
    order = jnp.argsort(keys)
    sk = keys[order]
    valid = jnp.ones((n,), bool) if mask is None else mask[order]
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), sk,
                                 num_segments=num_keys)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    # position within bucket
    ones = jnp.ones((n,), jnp.int32)
    pos_all = jnp.cumsum(ones) - 1
    slot = pos_all - jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jax.ops.segment_sum(ones, sk, num_segments=num_keys))[:-1]]
    )[sk]
    dest = sk * cap + slot
    flat = jnp.zeros((num_keys * cap, *chunk.shape[1:]), chunk.dtype)
    flat = flat.at[dest].set(jnp.where(valid.reshape(-1, *([1] * (chunk.ndim - 1))),
                                       chunk[order], 0))
    return flat.reshape(num_keys, cap, *chunk.shape[1:]), counts


def shuffle_sharded(x: jax.Array, mesh, axis: str = "model",
                    *, key=None, step=None):
    """All-to-all shuffle across a mesh axis (router as collective).

    x: (W, W, ...) mailbox layout — x[i, j] is the sub-block worker i
    sends to worker j; returns the inbox view y[j, i] = x[i, j] (the
    ZeroMQ 'shuffler' as one lax.all_to_all).  With ``key`` the blocks
    are AEAD-sealed so the wire carries only ciphertext (``step`` is then
    required, unique per round), and the result is (y, ok) with per-block
    MAC verdicts — repro.dist.collectives.
    """
    from repro.dist import collectives

    if key is not None:
        return collectives.secure_exchange(x, mesh, axis, key=key, step=step)
    return collectives.exchange(x, mesh, axis)


def route_keyed_sharded(x: jax.Array, row_keys: jax.Array, mesh,
                        axis: str = "model", *, key=None, step=None):
    """The ``keyed`` policy on a mesh: consistent hash-routing of rows to
    worker shards, optionally over sealed channels (dist.collectives)."""
    from repro.dist import collectives

    return collectives.keyed_route(x, row_keys, mesh, axis, key=key,
                                   step=step)
