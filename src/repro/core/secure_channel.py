"""AEAD-sealed tensor channels between pipeline-parallel stages.

The paper encrypts every stream between workers (SSL + enclave re-keying).
For model pipeline parallelism the analogous boundary is the activation
tensor crossing a stage boundary over ICI/DCN: ``protect`` seals it under
the edge key before the collective permute, ``unprotect`` opens it on the
receiving stage.  Sealing runs through the batched AEAD fast path
(:func:`repro.crypto.aead.seal_many`): one compiled program per activation
shape, held in a shape-keyed cache, so the per-tick cost after warmup is a
single elementwise pass.  ``protect_many``/``unprotect_many`` seal B
same-shape activations (e.g. every stage hand-off of one GPipe tick) under
B independent edge keys in one program.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import aead
from repro.crypto.keys import StageKey, resolve_key as _as_stage_key


def protect(key, step: int, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array, Tuple]:
    """Seal a tensor for the wire. Returns (ct_words, tag, meta)."""
    key = _as_stage_key(key)
    words, meta = aead.tensor_to_words(x)
    ct, tag = aead.seal_many(jnp.asarray(key.key)[None],
                             jnp.asarray(key.nonce(step))[None],
                             words[None])
    return ct[0], tag[0], meta


def unprotect(key, step: int, ct: jax.Array, tag: jax.Array,
              meta: Tuple) -> Tuple[jax.Array, jax.Array]:
    """Open a sealed tensor. Returns (tensor, ok)."""
    key = _as_stage_key(key)
    pt, ok = aead.open_many(jnp.asarray(key.key)[None],
                            jnp.asarray(key.nonce(step))[None],
                            ct[None], tag[None])
    return aead.words_to_tensor(pt[0], meta), ok[0]


def protect_many(keys: Sequence, steps: Sequence[int],
                 xs: jax.Array) -> Tuple[jax.Array, jax.Array, Tuple]:
    """Seal B same-shape tensors under B edge keys in ONE program.

    ``xs``: (B, *item) stacked activations; ``keys``/``steps``: length-B.
    Returns (ct (B, n_words), tags (B, 2), meta) with ``meta`` shared by
    every item (same shape/dtype framing).
    """
    keys = [_as_stage_key(k) for k in keys]
    words, meta = aead.tensor_to_words_batch(xs)
    kb = jnp.asarray(np.stack([np.asarray(k.key) for k in keys]))
    nb = jnp.asarray(np.stack([np.asarray(k.nonce(s))
                               for k, s in zip(keys, steps)]))
    ct, tags = aead.seal_many(kb, nb, words)
    return ct, tags, meta


def unprotect_many(keys: Sequence, steps: Sequence[int],
                   cts: jax.Array, tags: jax.Array, meta: Tuple
                   ) -> Tuple[jax.Array, jax.Array]:
    """Open B sealed tensors in ONE program. Returns ((B, *item), ok (B,))."""
    keys = [_as_stage_key(k) for k in keys]
    kb = jnp.asarray(np.stack([np.asarray(k.key) for k in keys]))
    nb = jnp.asarray(np.stack([np.asarray(k.nonce(s))
                               for k, s in zip(keys, steps)]))
    pt, ok = aead.open_many(kb, nb, cts, tags)
    return aead.words_to_tensor_batch(pt, meta), ok


class SecureChannel:
    """A sealed channel bound to one KeyDirectory edge.

    The channel never holds raw key material: every ``protect`` resolves
    the edge's *current-epoch* session key and allocates the next managed
    chunk counter from the directory (rotation resets it; the StageKey
    nonce guard backstops exhaustion).  ``unprotect`` takes the header
    ``(step, epoch)`` that ``protect`` returned, so chunks sealed before
    an epoch flip still open after it — the drain path.
    """

    def __init__(self, handle):
        self.handle = handle    # repro.attest.directory.EdgeHandle

    def protect(self, x: jax.Array):
        """-> ((step, epoch) header, ct, tag, meta)."""
        step = self.handle.next_counter()
        epoch = self.handle.epoch
        ct, tag, meta = protect(self.handle.key(), step, x)
        return (step, epoch), ct, tag, meta

    def unprotect(self, header: Tuple[int, int], ct: jax.Array,
                  tag: jax.Array, meta: Tuple):
        step, epoch = header
        return unprotect(self.handle.key(epoch), step, ct, tag, meta)

    def protect_window(self, xs: jax.Array):
        """Seal a (B, *item) window in ONE batched program under ONE
        atomically reserved counter block (EdgeHandle.reserve_window) —
        co-consumers of the edge can never land inside the block, and
        every row shares the window's epoch snapshot.

        -> ((base_step, epoch) header, ct (B, n_words), tags (B, 2), meta).
        """
        B = xs.shape[0]
        base, epoch = self.handle.reserve_window(B)
        k = self.handle.key(epoch)
        ct, tags, meta = protect_many([k] * B, range(base, base + B), xs)
        return (base, epoch), ct, tags, meta

    def unprotect_window(self, header: Tuple[int, int], cts: jax.Array,
                         tags: jax.Array, meta: Tuple):
        """Open a sealed window: -> ((B, *item), ok (B,) verdicts).  The
        header pins (base_step, epoch), so windows sealed before an epoch
        flip still open after it — the drain path, batched."""
        base, epoch = header
        B = cts.shape[0]
        k = self.handle.key(epoch)
        return unprotect_many([k] * B, range(base, base + B), cts, tags,
                              meta)


def sealed_ppermute(key, step: int, x: jax.Array, axis: str,
                    perm) -> Tuple[jax.Array, jax.Array]:
    """collective_permute of a sealed activation (inside shard_map).

    The wire (ICI) carries ciphertext; each stage re-opens locally.
    Returns (tensor, ok). Usable only where shapes are uniform across the
    permuted axis (pipeline microbatches are).  Ciphertext and tag ride a
    single packed payload, so each call is ONE collective.

    Every shard of ``axis`` seals a *different* plaintext under the same
    (key, step), so the sender's shard index is mixed into nonce word 0 —
    otherwise all shards would share one ChaCha20 keystream and XORing two
    wire ciphertexts would leak ``x_i ^ x_j`` (a two-time pad).  The
    receiver re-derives the sender's index from the static ``perm``.
    """
    key = _as_stage_key(key)
    words, meta = aead.tensor_to_words(x)
    me = jax.lax.axis_index(axis).astype(jnp.uint32)
    base = jnp.asarray(key.nonce(step), jnp.uint32)
    kw = jnp.asarray(key.key)[None]
    ct, tag = aead.seal_many(kw, base.at[0].set(me)[None], words[None])

    payload = jnp.concatenate([ct[0], tag[0]])
    payload_r = jax.lax.ppermute(payload, axis, perm)

    # src_for[dst] = src for each (src, dst) in perm; shards that receive
    # nothing get themselves (ppermute left zeros there — the MAC rejects)
    n = max((max(int(s), int(d)) for s, d in perm), default=0) + 1
    src_for = np.arange(n, dtype=np.uint32)
    for s, d in perm:
        src_for[int(d)] = int(s)
    sender = jnp.asarray(src_for)[jnp.minimum(me, np.uint32(n - 1))]
    pt, ok = aead.open_many(kw, base.at[0].set(sender)[None],
                            payload_r[:-2][None], payload_r[-2:][None])
    return aead.words_to_tensor(pt[0], meta), ok[0]
