"""AEAD-sealed tensor channels between pipeline-parallel stages.

The paper encrypts every stream between workers (SSL + enclave re-keying).
For model pipeline parallelism the analogous boundary is the activation
tensor crossing a stage boundary over ICI/DCN: ``protect`` seals it under
the edge key before the collective permute, ``unprotect`` opens it on the
receiving stage.  Because ChaCha20-CTR is a pure XOR stream and the CW-MAC
is jnp math, both compose with jit/shard_map and cost one elementwise pass.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.crypto import aead
from repro.crypto.keys import StageKey


def protect(key: StageKey, step: int, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array, Tuple]:
    """Seal a tensor for the wire. Returns (ct_words, tag, meta)."""
    words, meta = aead.tensor_to_words(x)
    nonce = jnp.asarray(key.nonce(step))
    ct, tag = aead.seal(jnp.asarray(key.key), nonce, words)
    return ct, tag, meta


def unprotect(key: StageKey, step: int, ct: jax.Array, tag: jax.Array,
              meta: Tuple) -> Tuple[jax.Array, jax.Array]:
    """Open a sealed tensor. Returns (tensor, ok)."""
    nonce = jnp.asarray(key.nonce(step))
    pt, ok = aead.open_(jnp.asarray(key.key), nonce, ct, tag)
    return aead.words_to_tensor(pt, meta), ok


def sealed_ppermute(key: StageKey, step: int, x: jax.Array, axis: str,
                    perm) -> Tuple[jax.Array, jax.Array]:
    """collective_permute of a sealed activation (inside shard_map).

    The wire (ICI) carries ciphertext; each stage re-opens locally.
    Returns (tensor, ok). Usable only where shapes are uniform across the
    permuted axis (pipeline microbatches are).
    """
    ct, tag, meta = protect(key, step, x)
    ct_r = jax.lax.ppermute(ct, axis, perm)
    tag_r = jax.lax.ppermute(tag, axis, perm)
    return unprotect(key, step, ct_r, tag_r, meta)
