from repro.crypto import aead, chacha20, cwmac, keys  # noqa: F401
