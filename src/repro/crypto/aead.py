"""AEAD over uint32 word streams: ChaCha20-CTR + CW-MAC (encrypt-then-MAC).

Mirrors the ChaCha20-Poly1305 construction: the MAC keys (r1,s1,r2,s2) are
derived from keystream block 0 (counter=0); payload encryption starts at
counter=1.  ``seal``/``open`` operate on flat uint32 arrays — the chunked
stream layer (repro.core) handles byte framing and per-chunk nonces.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import chacha20, cwmac

U32 = jnp.uint32
P31 = np.uint32(0x7FFFFFFF)


def derive_mac_keys(key: jax.Array, nonce: jax.Array) -> Tuple[jax.Array, ...]:
    """(r1, s1, r2, s2) from keystream block 0, clamped below 2^31-1."""
    blk = chacha20.chacha20_block(key, nonce,
                                  jnp.zeros((1,), U32))[0]  # (16,) u32
    clamp = lambda w: jnp.minimum(w & P31, P31 - np.uint32(1))
    return clamp(blk[0]), clamp(blk[1]), clamp(blk[2]), clamp(blk[3])


def seal(key: jax.Array, nonce: jax.Array,
         plaintext: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (ciphertext (N,) u32, tag (2,) u32)."""
    ct = chacha20.encrypt_words(key, nonce, plaintext, counter0=1)
    r1, s1, r2, s2 = derive_mac_keys(key, nonce)
    tag = cwmac.mac2(ct, r1, s1, r2, s2)
    return ct, tag


def open_(key: jax.Array, nonce: jax.Array, ciphertext: jax.Array,
          tag: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (plaintext, ok: bool scalar). Constant-shape (jit-safe): the caller
    decides what to do with ok=False (the stream layer drops the chunk)."""
    r1, s1, r2, s2 = derive_mac_keys(key, nonce)
    expect = cwmac.mac2(ciphertext, r1, s1, r2, s2)
    ok = jnp.all(expect == tag)
    pt = chacha20.decrypt_words(key, nonce, ciphertext, counter0=1)
    return pt, ok


# ---------------------------------------------------------------------------
# dtype framing helpers (tensors <-> uint32 words)
# ---------------------------------------------------------------------------


def tensor_to_words(x: jax.Array) -> Tuple[jax.Array, Tuple]:
    """Bit-cast any tensor to a flat uint32 word array (padded to 4 bytes)."""
    raw = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1) \
        if x.dtype != jnp.uint32 else x.reshape(-1)
    if x.dtype == jnp.uint32:
        return raw, (x.shape, str(x.dtype), 0)
    pad = (-raw.shape[0]) % 4
    raw = jnp.pad(raw, (0, pad))
    words = jax.lax.bitcast_convert_type(raw.reshape(-1, 4), jnp.uint32)
    return words.reshape(-1), (x.shape, str(x.dtype), pad)


def words_to_tensor(words: jax.Array, meta: Tuple) -> jax.Array:
    shape, dtype, pad = meta
    if dtype == "uint32":
        return words.reshape(shape)
    raw = jax.lax.bitcast_convert_type(words.reshape(-1, 1),
                                       jnp.uint8).reshape(-1)
    if pad:
        raw = raw[:-pad]
    n = np.prod(shape, dtype=np.int64) if shape else 1
    itemsize = jnp.dtype(dtype).itemsize
    flat = jax.lax.bitcast_convert_type(
        raw.reshape(int(n), itemsize), jnp.dtype(dtype)).reshape(shape)
    return flat
