"""AEAD over uint32 word streams: ChaCha20-CTR + CW-MAC (encrypt-then-MAC).

Mirrors the ChaCha20-Poly1305 construction: the MAC keys (r1,s1,r2,s2) are
derived from keystream block 0 (counter=0); payload encryption starts at
counter=1.  ``seal``/``open_`` operate on flat uint32 arrays and derive the
MAC-key block and the payload keystream from ONE ChaCha20 pass over
counters 0..N (a single fused ``chacha20_block`` invocation, not two
separate keystream passes).  The chunked stream layer (repro.core) handles
byte framing and per-chunk nonces.

Batched fast path: :func:`seal_many` / :func:`open_many` process a whole
(B, n_words) batch in one compiled program, dispatching to the Pallas
``kernels/chacha20`` + ``kernels/cwmac`` backends (interpret on CPU,
compiled on TPU) with the pure-jnp reference as oracle/fallback.  Compiled
programs are held in a shape-keyed cache — every round of
``secure_exchange``/``keyed_route``/``sealed_ppermute`` reuses identical
(B, n_words) shapes, so one compile amortizes over all subsequent rounds
(:func:`fastpath_stats` exposes the hit/compile counters).
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import chacha20, cwmac
from repro.obs.metrics import REGISTRY as _METRICS

U32 = jnp.uint32
P31 = np.uint32(0x7FFFFFFF)


def _clamp(w: jax.Array) -> jax.Array:
    return jnp.minimum(w & P31, P31 - np.uint32(1))


def derive_mac_keys(key: jax.Array, nonce: jax.Array) -> Tuple[jax.Array, ...]:
    """(r1, s1, r2, s2) from keystream block 0, clamped below 2^31-1."""
    blk = chacha20.chacha20_block(key, nonce,
                                  jnp.zeros((1,), U32))[0]  # (16,) u32
    return _clamp(blk[0]), _clamp(blk[1]), _clamp(blk[2]), _clamp(blk[3])


def _fused_stream(key: jax.Array, nonce: jax.Array, n_words: int
                  ) -> Tuple[Tuple[jax.Array, ...], jax.Array]:
    """MAC keys + payload keystream from ONE pass over counters 0..N."""
    n_blocks = (n_words + 15) // 16
    blks = chacha20.chacha20_block(
        key, nonce, jnp.arange(n_blocks + 1, dtype=U32))  # (n_blocks+1, 16)
    mk = tuple(_clamp(blks[0, i]) for i in range(4))
    ks = blks[1:].reshape(-1)[:n_words]
    return mk, ks


def seal(key: jax.Array, nonce: jax.Array,
         plaintext: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (ciphertext (N,) u32, tag (2,) u32)."""
    (r1, s1, r2, s2), ks = _fused_stream(key, nonce, plaintext.shape[0])
    ct = plaintext ^ ks
    tag = cwmac.mac2(ct, r1, s1, r2, s2)
    return ct, tag


def open_(key: jax.Array, nonce: jax.Array, ciphertext: jax.Array,
          tag: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (plaintext, ok: bool scalar). Constant-shape (jit-safe): the caller
    decides what to do with ok=False (the stream layer drops the chunk)."""
    (r1, s1, r2, s2), ks = _fused_stream(key, nonce, ciphertext.shape[0])
    expect = cwmac.mac2(ciphertext, r1, s1, r2, s2)
    ok = jnp.all(expect == tag)
    return ciphertext ^ ks, ok


# ---------------------------------------------------------------------------
# batched fast path: one compiled program per (B, n_words) shape
# ---------------------------------------------------------------------------

BACKENDS = ("pallas", "jnp")
_DEFAULT_BACKEND = "pallas"

_COMPILE_CACHE: "OrderedDict[Tuple, Any]" = OrderedDict()
_COMPILE_CACHE_MAX = 64
# registered instruments (repro.obs.metrics) — fastpath_stats()/reset_*
# below are the legacy shims over these
_FP_COMPILES = _METRICS.counter("aead.fastpath.compiles")
_FP_HITS = _METRICS.counter("aead.fastpath.hits")
# every call below launches exactly ONE cached compiled program, so the
# dispatch counters increment here in the eager wrappers — never inside
# traced code, where an inc() fires once at trace time and disappears
_DISPATCHES = _METRICS.counter("device.dispatches")
_DISP_SEAL = _METRICS.counter("device.dispatches.aead.seal_many")
_DISP_OPEN = _METRICS.counter("device.dispatches.aead.open_many")
_DISP_MACKEYS = _METRICS.counter("device.dispatches.aead.mac_keys_many")
_DISP_MAC2 = _METRICS.counter("device.dispatches.aead.mac2_many")


def _resolve_backend(backend: Optional[str]) -> str:
    backend = backend or _DEFAULT_BACKEND
    if backend not in BACKENDS:
        raise ValueError(f"unknown AEAD backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    return backend


def _batch_rows(key: jax.Array, nonces: jax.Array, payload: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Flatten a (B, n) batch into per-block rows covering counters 0..N.

    Row (b, 0) carries zeros (its XOR output is raw keystream block 0, the
    MAC-key block); rows (b, 1..N) carry the payload blocks.  The whole
    batch is then ONE row-parallel cipher invocation.
    """
    B, n = payload.shape
    n_blocks = (n + 15) // 16
    R = n_blocks + 1
    data = jnp.pad(payload.astype(U32), ((0, 0), (0, n_blocks * 16 - n)))
    rows = jnp.concatenate([jnp.zeros((B, 1, 16), U32),
                            data.reshape(B, n_blocks, 16)], axis=1)
    counters = jnp.tile(jnp.arange(R, dtype=U32), B)
    row_nonces = jnp.repeat(nonces.astype(U32), R, axis=0)
    row_keys = key.astype(U32) if key.ndim == 1 \
        else jnp.repeat(key.astype(U32), R, axis=0)
    return row_keys, row_nonces, rows.reshape(B * R, 16), counters


def _cipher_pass(key, nonces, payload, backend):
    """-> (mac_keys (B, 4) clamped, payload ^ keystream (B, n))."""
    B, n = payload.shape
    row_keys, row_nonces, rows, counters = _batch_rows(key, nonces, payload)
    if backend == "pallas":
        from repro.kernels.chacha20 import ops as chacha_ops
        out = chacha_ops.xor_rows(row_keys, row_nonces, counters, rows)
    else:
        if row_keys.ndim == 1:
            row_keys = jnp.broadcast_to(row_keys[None, :],
                                        (rows.shape[0], 8))
        out = rows ^ chacha20.chacha20_block_rows(row_keys, row_nonces,
                                                  counters)
    out = out.reshape(B, -1, 16)
    mk = _clamp(out[:, 0, :4])
    return mk, out[:, 1:, :].reshape(B, -1)[:, :n]


def _mac2_batch(words, mk, backend):
    if backend == "pallas":
        from repro.kernels.cwmac import ops as cwmac_ops
        return cwmac_ops.mac2_batch(words, mk[:, 0], mk[:, 1],
                                    mk[:, 2], mk[:, 3])
    return cwmac.mac2_batch(words, mk[:, 0], mk[:, 1], mk[:, 2], mk[:, 3])


def _seal_words(key, nonces, words, *, backend):
    mk, ct = _cipher_pass(key, nonces, words, backend)
    return ct, _mac2_batch(ct, mk, backend)


def _open_words(key, nonces, cts, tags, *, backend):
    mk, pt = _cipher_pass(key, nonces, cts, backend)
    expect = _mac2_batch(cts, mk, backend)
    return pt, jnp.all(expect == tags, axis=-1)


def _mac_keys_rows(key, nonces):
    """(B, 4) clamped CW-MAC keys from keystream block 0 of each row —
    the batched form of :func:`derive_mac_keys` (one rolled ChaCha pass)."""
    zeros = jnp.zeros((nonces.shape[0],), U32)
    blk = chacha20.chacha20_block_rows(key, nonces, zeros)
    return _clamp(blk[:, :4])


def _mac2_words(words, mac_keys, *, backend):
    return _mac2_batch(words, mac_keys, backend)


def _cached_program(op: str, B: int, n_words: int, backend: str,
                    per_item_key: bool):
    """Shape-keyed compile cache: one jitted program per batch signature."""
    ck = (op, B, n_words, backend, per_item_key)
    fn = _COMPILE_CACHE.get(ck)
    if fn is None:
        _FP_COMPILES.inc()
        impl = {"seal": _seal_words, "open": _open_words,
                "mac2": _mac2_words}.get(op)
        if impl is None:                       # mackeys takes no backend kw
            fn = jax.jit(_mac_keys_rows)
        else:
            fn = jax.jit(functools.partial(impl, backend=backend))
        _COMPILE_CACHE[ck] = fn
        while len(_COMPILE_CACHE) > _COMPILE_CACHE_MAX:
            _COMPILE_CACHE.popitem(last=False)
    else:
        _FP_HITS.inc()
        _COMPILE_CACHE.move_to_end(ck)
    return fn


def _check_batch(key, nonces, words, what):
    if words.ndim != 2:
        raise ValueError(f"{what} expects (B, n_words), got {words.shape}")
    if words.dtype != jnp.uint32:
        # dtype is part of a program's signature but NOT of the cache key:
        # admitting non-u32 words would silently retrace behind a "hit"
        raise ValueError(f"{what} expects uint32 words (bitcast 4-byte "
                         f"payloads first), got {words.dtype}")
    if nonces.shape != (words.shape[0], 3):
        raise ValueError(f"{what} expects nonces (B, 3) matching B="
                         f"{words.shape[0]}, got {nonces.shape}")
    if key.shape not in ((8,), (words.shape[0], 8)):
        raise ValueError(f"{what} expects key (8,) or (B, 8), "
                         f"got {key.shape}")


def seal_many(key: jax.Array, nonces: jax.Array, words: jax.Array, *,
              backend: Optional[str] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Batched AEAD seal: a whole (B, n_words) batch in ONE program.

    ``key``: (8,) u32 shared or (B, 8) per-item keys; ``nonces``: (B, 3);
    ``words``: (B, n_words) u32.  Returns (ct (B, n_words), tags (B, 2)),
    item-wise identical to ``vmap(seal)``.  ``backend``: "pallas" (default;
    interpret on CPU, compiled on TPU) or "jnp" (reference oracle).
    """
    backend = _resolve_backend(backend)
    key, nonces, words = map(jnp.asarray, (key, nonces, words))
    _check_batch(key, nonces, words, "seal_many")
    fn = _cached_program("seal", words.shape[0], words.shape[1], backend,
                         key.ndim == 2)
    _DISPATCHES.inc()
    _DISP_SEAL.inc()
    return fn(key.astype(U32), nonces.astype(U32), words)


def open_many(key: jax.Array, nonces: jax.Array, cts: jax.Array,
              tags: jax.Array, *, backend: Optional[str] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Batched AEAD open: -> (pt (B, n_words), ok (B,) bool verdicts)."""
    backend = _resolve_backend(backend)
    key, nonces, cts, tags = map(jnp.asarray, (key, nonces, cts, tags))
    _check_batch(key, nonces, cts, "open_many")
    if tags.shape != (cts.shape[0], 2):
        raise ValueError(f"open_many expects tags (B, 2), got {tags.shape}")
    fn = _cached_program("open", cts.shape[0], cts.shape[1], backend,
                         key.ndim == 2)
    _DISPATCHES.inc()
    _DISP_OPEN.inc()
    return fn(key.astype(U32), nonces.astype(U32), cts, tags.astype(U32))


def derive_mac_keys_many(key: jax.Array, nonces: jax.Array) -> jax.Array:
    """Batched MAC-key derivation: (B, 4) clamped (r1, s1, r2, s2) rows.

    ``key``: (8,) shared or (B, 8) per-item; ``nonces``: (B, 3).  Row b
    equals ``derive_mac_keys(key_b, nonces[b])`` — used by the enclave
    executor's window path, which MACs ciphertext *outside* the fused
    kernel (ciphertext is public) but must not pay B scalar dispatches.
    Programs share the seal/open compile cache (:func:`fastpath_stats`).
    """
    key, nonces = jnp.asarray(key), jnp.asarray(nonces)
    if nonces.ndim != 2 or nonces.shape[1] != 3:
        raise ValueError(f"derive_mac_keys_many expects nonces (B, 3), "
                         f"got {nonces.shape}")
    fn = _cached_program("mackeys", nonces.shape[0], 0, "jnp",
                         key.ndim == 2)
    _DISPATCHES.inc()
    _DISP_MACKEYS.inc()
    return fn(key.astype(U32), nonces.astype(U32))


def mac2_many(words: jax.Array, mac_keys: jax.Array, *,
              backend: Optional[str] = None) -> jax.Array:
    """Batched dual CW-MAC: (B, n_words) u32 under (B, 4) mac-key rows ->
    (B, 2) tags, one cached program per (B, n_words) shape."""
    backend = _resolve_backend(backend)
    words, mac_keys = jnp.asarray(words), jnp.asarray(mac_keys)
    if words.ndim != 2 or mac_keys.shape != (words.shape[0], 4):
        raise ValueError(f"mac2_many expects words (B, n) and mac_keys "
                         f"(B, 4); got {words.shape} / {mac_keys.shape}")
    fn = _cached_program("mac2", words.shape[0], words.shape[1], backend,
                         True)
    _DISPATCHES.inc()
    _DISP_MAC2.inc()
    return fn(words.astype(U32), mac_keys.astype(U32))


def fastpath_stats() -> Dict[str, int]:
    """Compile-cache counters: ``compiles`` (cache misses -> new programs),
    ``hits`` (shape already compiled), ``cached`` (resident programs).

    Shim over the registered counters ``aead.fastpath.compiles`` /
    ``aead.fastpath.hits`` in :data:`repro.obs.metrics.REGISTRY`.
    """
    return {"compiles": int(_FP_COMPILES.value),
            "hits": int(_FP_HITS.value),
            "cached": len(_COMPILE_CACHE)}


def reset_fastpath_cache() -> None:
    """Drop all cached programs and zero the counters (tests/benchmarks
    that need a genuinely cold cache — recompiles cost ~2 s/shape)."""
    _COMPILE_CACHE.clear()
    _FP_COMPILES.reset()
    _FP_HITS.reset()


def reset_fastpath_stats() -> None:
    """Zero the hit/compile counters but KEEP the compiled programs —
    enough for order-independent cache-hit assertions without re-paying
    warm compiles (the per-module test fixture)."""
    _FP_COMPILES.reset()
    _FP_HITS.reset()


# ---------------------------------------------------------------------------
# dtype framing helpers (tensors <-> uint32 words)
# ---------------------------------------------------------------------------


def tensor_to_words(x: jax.Array) -> Tuple[jax.Array, Tuple]:
    """Bit-cast any tensor to a flat uint32 word array (padded to 4 bytes)."""
    raw = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1) \
        if x.dtype != jnp.uint32 else x.reshape(-1)
    if x.dtype == jnp.uint32:
        return raw, (x.shape, str(x.dtype), 0)
    pad = (-raw.shape[0]) % 4
    raw = jnp.pad(raw, (0, pad))
    words = jax.lax.bitcast_convert_type(raw.reshape(-1, 4), jnp.uint32)
    return words.reshape(-1), (x.shape, str(x.dtype), pad)


def words_to_tensor(words: jax.Array, meta: Tuple) -> jax.Array:
    """Inverse of :func:`tensor_to_words`: rebuild the original tensor
    from its flat u32 words and framing ``meta`` (shape, dtype, pad)."""
    shape, dtype, pad = meta
    if dtype == "uint32":
        return words.reshape(shape)
    raw = jax.lax.bitcast_convert_type(words.reshape(-1, 1),
                                       jnp.uint8).reshape(-1)
    if pad:
        raw = raw[:-pad]
    n = np.prod(shape, dtype=np.int64) if shape else 1
    itemsize = jnp.dtype(dtype).itemsize
    flat = jax.lax.bitcast_convert_type(
        raw.reshape(int(n), itemsize), jnp.dtype(dtype)).reshape(shape)
    return flat


def tensor_to_words_batch(x: jax.Array) -> Tuple[jax.Array, Tuple]:
    """(B, *item) tensor batch -> ((B, n_words) u32, meta).

    Row b carries exactly the words ``tensor_to_words(x[b])`` would — the
    batch form exists so :func:`seal_many` can frame B same-shape tensors
    without B separate dispatches.
    """
    B = x.shape[0]
    item_shape = x.shape[1:]
    if x.dtype == jnp.uint32:
        return x.reshape(B, -1), (item_shape, "uint32", 0)
    raw = jax.lax.bitcast_convert_type(x.reshape(B, -1),
                                       jnp.uint8).reshape(B, -1)
    pad = (-raw.shape[1]) % 4
    raw = jnp.pad(raw, ((0, 0), (0, pad)))
    words = jax.lax.bitcast_convert_type(raw.reshape(B, -1, 4), jnp.uint32)
    return words, (item_shape, str(x.dtype), pad)


def words_to_tensor_batch(words: jax.Array, meta: Tuple) -> jax.Array:
    """Inverse of :func:`tensor_to_words_batch`: (B, n_words) -> (B, *item)."""
    item_shape, dtype, pad = meta
    B = words.shape[0]
    if dtype == "uint32":
        return words.reshape((B,) + tuple(item_shape))
    raw = jax.lax.bitcast_convert_type(words.reshape(B, -1, 1),
                                       jnp.uint8).reshape(B, -1)
    if pad:
        raw = raw[:, :-pad]
    itemsize = jnp.dtype(dtype).itemsize
    flat = jax.lax.bitcast_convert_type(
        raw.reshape(B, -1, itemsize), jnp.dtype(dtype))
    return flat.reshape((B,) + tuple(item_shape))
