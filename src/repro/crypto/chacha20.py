"""ChaCha20 stream cipher in pure JAX (uint32 lane arithmetic).

The cipher is embarrassingly parallel in counter mode: every 64-byte block
(16 uint32 words) derives its keystream independently from (key, nonce,
counter).  That maps perfectly onto TPU vector lanes — each lane processes
one block; the 20 rounds are elementwise adds/xors/rotates.

This module is the jnp reference implementation and the oracle for the
Pallas kernel in ``repro/kernels/chacha20``.  RFC 7539 test vectors are
checked in tests/test_crypto.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
CONSTANTS = np.array([0x61707865, 0x3320646e, 0x79622d32, 0x6b206574],
                     dtype=np.uint32)  # "expand 32-byte k"


def _rotl(x: jax.Array, n: int) -> jax.Array:
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def _quarter(state, a, b, c, d):
    """One quarter round on state column vectors (dict idx -> (N,) u32)."""
    sa, sb, sc, sd = state[a], state[b], state[c], state[d]
    sa = sa + sb
    sd = _rotl(sd ^ sa, 16)
    sc = sc + sd
    sb = _rotl(sb ^ sc, 12)
    sa = sa + sb
    sd = _rotl(sd ^ sa, 8)
    sc = sc + sd
    sb = _rotl(sb ^ sc, 7)
    state[a], state[b], state[c], state[d] = sa, sb, sc, sd


def chacha20_block_rows(key: jax.Array, nonces: jax.Array,
                        counters: jax.Array) -> jax.Array:
    """Keystream blocks with an independent (nonce, counter) per row.

    key: (8,) u32 shared, or (N, 8) u32 per-row keys; nonces: (N, 3) u32;
    counters: (N,) u32.  Returns (N, 16) u32 keystream.  This is the
    primitive behind the batched AEAD fast path: one invocation covers
    every (batch item, counter) pair of a whole seal/open batch.
    """
    N = counters.shape[0]
    cols = []
    for i in range(4):
        cols.append(jnp.broadcast_to(jnp.asarray(CONSTANTS[i], U32), (N,)))
    for i in range(8):
        k = key[:, i] if key.ndim == 2 else jnp.broadcast_to(key[i], (N,))
        cols.append(k.astype(U32))
    cols.append(counters.astype(U32))
    for i in range(3):
        cols.append(nonces[:, i].astype(U32))

    def double_round(_, s):
        s = list(s)
        _quarter(s, 0, 4, 8, 12)
        _quarter(s, 1, 5, 9, 13)
        _quarter(s, 2, 6, 10, 14)
        _quarter(s, 3, 7, 11, 15)
        _quarter(s, 0, 5, 10, 15)
        _quarter(s, 1, 6, 11, 12)
        _quarter(s, 2, 7, 8, 13)
        _quarter(s, 3, 4, 9, 14)
        return tuple(s)

    # rolled loop (not unrolled): a 10x smaller XLA graph compiles ~10x
    # faster, which is what makes the shape-keyed compile cache affordable
    state = jax.lax.fori_loop(0, 10, double_round, tuple(cols))

    out = [s + c for s, c in zip(state, cols)]
    return jnp.stack(out, axis=-1)  # (N, 16)


def chacha20_block(key: jax.Array, nonce: jax.Array,
                   counters: jax.Array) -> jax.Array:
    """Keystream blocks.

    key: (8,) u32; nonce: (3,) u32; counters: (N,) u32.
    Returns (N, 16) u32 keystream.
    """
    N = counters.shape[0]
    nonces = jnp.broadcast_to(jnp.asarray(nonce, U32)[None, :], (N, 3))
    return chacha20_block_rows(key, nonces, counters)


def keystream(key: jax.Array, nonce: jax.Array, n_words: int,
              counter0: int = 1) -> jax.Array:
    """Flat keystream of n_words uint32 (padded up to whole blocks)."""
    n_blocks = (n_words + 15) // 16
    counters = counter0 + jnp.arange(n_blocks, dtype=U32)
    ks = chacha20_block(key, nonce, counters).reshape(-1)
    return ks[:n_words]


def encrypt_words(key: jax.Array, nonce: jax.Array, words: jax.Array,
                  counter0: int = 1) -> jax.Array:
    """XOR a flat (N,) uint32 array with the keystream. Involutive."""
    ks = keystream(key, nonce, words.shape[0], counter0)
    return words ^ ks


decrypt_words = encrypt_words  # XOR stream cipher is its own inverse
