"""Carter-Wegman polynomial MAC over GF(2^31 - 1) (Mersenne prime M31).

This replaces Poly1305 in the TPU hot path (DESIGN.md §2): Poly1305's
130-bit limb arithmetic needs 64-bit multiplies, which TPU vector lanes do
not have.  A polynomial-evaluation MAC over M31 uses only 32-bit integer
ops (with 16-bit split multiplication) and admits a *parallel* form

    tag = ( sum_i m_i * r^(n-i) + s ) mod p

so per-tile partial sums can be combined in a tree — the MAC of a 100 MB
stream parallelizes across lanes/cores like the cipher itself.

Security note (honest): a single M31 evaluation gives ~31-bit forgery
bound; we evaluate with two independent keys and concatenate (62-bit tag),
which is adequate for integrity (not signatures) inside a session.  The
host-side Poly1305 (poly1305_host.py) remains for sealed storage.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
P31 = np.uint32(0x7FFFFFFF)  # 2^31 - 1


def _fold31(x: jax.Array) -> jax.Array:
    """Reduce a uint32 (< 2^32) mod 2^31-1 (one fold + conditional sub)."""
    x = (x & P31) + (x >> np.uint32(31))
    return jnp.where(x >= P31, x - P31, x)


def addmod(a: jax.Array, b: jax.Array) -> jax.Array:
    return _fold31(a + b)  # a,b < 2^31 so a+b < 2^32: safe in u32


def mulmod(a: jax.Array, b: jax.Array) -> jax.Array:
    """(a*b) mod (2^31-1) for a,b < 2^31 using 16-bit split multiplies."""
    a1 = a >> np.uint32(16)          # < 2^15
    a0 = a & np.uint32(0xFFFF)       # < 2^16
    b1 = b >> np.uint32(16)          # < 2^15
    b0 = b & np.uint32(0xFFFF)
    t00 = a0 * b0                    # < 2^32 (fits u32)
    t01 = a0 * b1                    # < 2^31
    t10 = a1 * b0                    # < 2^31
    t11 = a1 * b1                    # < 2^30
    mid = t01 + t10                  # < 2^32
    # value = t11*2^32 + mid*2^16 + t00  (mod p: 2^32 = 2, 2^31 = 1)
    mid_h = mid >> np.uint32(15)     # * 2^31 -> * 1
    mid_l = (mid & np.uint32(0x7FFF)) << np.uint32(16)
    acc = _fold31(t00)
    acc = addmod(acc, _fold31(t11 * np.uint32(2)))
    acc = addmod(acc, _fold31(mid_h))
    acc = addmod(acc, _fold31(mid_l))
    return acc


def _to_limbs(words: jax.Array) -> jax.Array:
    """Split (N,) uint32 into (2N,) 16-bit limbs (< p) for injectivity."""
    lo = words & np.uint32(0xFFFF)
    hi = words >> np.uint32(16)
    return jnp.stack([lo, hi], axis=-1).reshape(-1)


def r_powers(r: jax.Array, n: int) -> jax.Array:
    """[r^n, r^(n-1), ..., r^1] mod p via log-depth doubling.

    O(log n) sequential steps of vectorized mulmods (r^{i+m} = r^i * r^m),
    not an O(n) scan — the MAC of an N-word chunk stays parallel end to end
    (EXPERIMENTS.md §Perf, pipeline iteration)."""
    asc = jnp.asarray(r, U32).reshape(1)
    while asc.shape[0] < n:
        asc = jnp.concatenate([asc, mulmod(asc, asc[-1])])
    return asc[:n][::-1]


def mac(words: jax.Array, r: jax.Array, s: jax.Array) -> jax.Array:
    """tag = (sum_i limb_i * r^(n-i) + s) mod p. All scalars u32 < p.

    Parallel form: the elementwise multiply + sum is one reduction, so XLA
    (and the Pallas kernel) can tree-reduce across lanes.
    """
    limbs = _to_limbs(words)
    n = limbs.shape[0]
    ps = r_powers(r, n)
    # elementwise mulmod then tree add-mod (log-depth via binary fold)
    terms = mulmod(limbs, ps)
    acc = terms
    while acc.shape[0] > 1:
        if acc.shape[0] % 2:
            acc = jnp.concatenate([acc, jnp.zeros((1,), U32)])
        acc = addmod(acc[0::2], acc[1::2])
    return addmod(acc[0], s)


def mac2(words: jax.Array, r1: jax.Array, s1: jax.Array,
         r2: jax.Array, s2: jax.Array) -> jax.Array:
    """Two independent M31 evaluations -> (2,) u32 tag (~62-bit bound)."""
    return jnp.stack([mac(words, r1, s1), mac(words, r2, s2)])


# ---------------------------------------------------------------------------
# batched forms (B independent messages / keys in one program)
# ---------------------------------------------------------------------------


def to_limbs_batch(words: jax.Array) -> jax.Array:
    """(B, N) uint32 -> (B, 2N) 16-bit limbs, per-row layout of _to_limbs."""
    lo = words & np.uint32(0xFFFF)
    hi = words >> np.uint32(16)
    return jnp.stack([lo, hi], axis=-1).reshape(words.shape[0], -1)


def r_powers_batch(r: jax.Array, n: int) -> jax.Array:
    """Per-row [r_b^n .. r_b^1]: (B,) keys -> (B, n) powers, log-doubling."""
    asc = jnp.asarray(r, U32).reshape(-1, 1)
    while asc.shape[1] < n:
        asc = jnp.concatenate([asc, mulmod(asc, asc[:, -1:])], axis=1)
    return asc[:, :n][:, ::-1]


def mac_batch(words: jax.Array, r: jax.Array, s: jax.Array) -> jax.Array:
    """Row-wise MAC: (B, N) words under (B,) keys -> (B,) tags.

    Same polynomial as :func:`mac`, but the elementwise mulmod and the
    log-depth add-mod tree run over the whole batch at once — one program
    MACs every block of a mailbox round.
    """
    limbs = to_limbs_batch(words)
    ps = r_powers_batch(r, limbs.shape[1])
    acc = mulmod(limbs, ps)
    while acc.shape[1] > 1:
        if acc.shape[1] % 2:
            acc = jnp.concatenate(
                [acc, jnp.zeros((acc.shape[0], 1), U32)], axis=1)
        acc = addmod(acc[:, 0::2], acc[:, 1::2])
    return addmod(acc[:, 0], s)


def mac2_batch(words: jax.Array, r1: jax.Array, s1: jax.Array,
               r2: jax.Array, s2: jax.Array) -> jax.Array:
    """Row-wise dual-key MAC: (B, N) words -> (B, 2) tags.

    Both evaluations share one kernel pass: the (r1, s1) and (r2, s2) rows
    are stacked into a single (2B,)-key batch.
    """
    B = words.shape[0]
    tags = mac_batch(jnp.concatenate([words, words]),
                     jnp.concatenate([jnp.asarray(r1, U32).reshape(-1),
                                      jnp.asarray(r2, U32).reshape(-1)]),
                     jnp.concatenate([jnp.asarray(s1, U32).reshape(-1),
                                      jnp.asarray(s2, U32).reshape(-1)]))
    return jnp.stack([tags[:B], tags[B:]], axis=-1)


def mac_reference(words: np.ndarray, r: int, s: int) -> int:
    """Host-side oracle with Python ints (used by tests)."""
    p = (1 << 31) - 1
    limbs = []
    for w in np.asarray(words, dtype=np.uint64):
        limbs += [int(w) & 0xFFFF, int(w) >> 16]
    acc = 0
    for m in limbs:
        acc = ((acc + m) * r) % p
    return (acc + s) % p
