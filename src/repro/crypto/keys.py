"""Session keys per pipeline stage.

As in the paper (§4): "we assume that attestation and key establishment was
previously performed. As a result, keys safely reside within the enclave."
Key material is derived deterministically from a root key + stage name so
every worker of a stage (and its downstream router) agrees without a wire
protocol; nonces are (stage_id, chunk_counter) pairs, never reused.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32


@dataclass(frozen=True)
class StageKey:
    key: np.ndarray          # (8,) uint32 — ChaCha20 key
    stage_id: int

    def nonce(self, chunk_counter: int) -> np.ndarray:
        # Nonce depends only on the chunk counter: edge keys are already
        # unique per edge (so no cross-edge nonce reuse), and the fused
        # enclave kernel re-encrypts under the *outbound* key with the same
        # nonce — sender and receiver must agree on it without knowing each
        # other's stage ids.
        return np.array([0,
                         chunk_counter & 0xFFFFFFFF,
                         (chunk_counter >> 32) & 0xFFFFFFFF],
                        dtype=np.uint32)


def derive_stage_key(root: bytes, stage_name: str, stage_id: int) -> StageKey:
    h = hashlib.sha256(root + b"|" + stage_name.encode()).digest()
    key = np.frombuffer(h, dtype="<u4").copy()
    return StageKey(key=key, stage_id=stage_id)


def root_key_from_seed(seed: int) -> bytes:
    return hashlib.sha256(f"repro-root-{seed}".encode()).digest()
