"""Session keys per pipeline stage.

The paper (§4) assumes "attestation and key establishment was previously
performed" — that assumption is now implemented by ``repro.attest``:
session keys are established per edge by the quote-checked DH handshake
(`repro.attest.handshake`) and owned/ratcheted/revoked by
`repro.attest.directory.KeyDirectory` (which builds StageKeys via
``repro.attest.rotation.key_from_bytes``, not this module's derivation).
This module defines the key *container* and the nonce discipline;
``derive_stage_key`` survives only as the legacy root-seed derivation
exercised by the crypto unit tests (a grep test asserts nothing else
calls it).

Nonces are (domain, chunk_counter) pairs, never reused under one key: the
counter occupies nonce words 1..2 (64 bits) and :meth:`StageKey.nonce`
raises :class:`NonceExhaustedError` before it can wrap — long-running
streams must rotate keys (``KeyDirectory.advance_epoch`` resets the
per-edge counters) well before that hard stop.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32

# The chunk counter rides in two u32 nonce words; reusing a (key, nonce)
# pair is a two-time pad, so the guard below is a hard error, not a wrap.
NONCE_COUNTER_BITS = 64
NONCE_COUNTER_MAX = (1 << NONCE_COUNTER_BITS) - 1


class NonceExhaustedError(RuntimeError):
    """The 64-bit chunk counter is exhausted for this key; rotate first
    (repro.attest.rotation / KeyDirectory.advance_epoch)."""


@dataclass(frozen=True)
class StageKey:
    key: np.ndarray          # (8,) uint32 — ChaCha20 key
    stage_id: int

    def nonce(self, chunk_counter: int) -> np.ndarray:
        # Nonce depends only on the chunk counter: edge keys are already
        # unique per edge (so no cross-edge nonce reuse), and the fused
        # enclave kernel re-encrypts under the *outbound* key with the same
        # nonce — sender and receiver must agree on it without knowing each
        # other's stage ids.
        if not 0 <= chunk_counter <= NONCE_COUNTER_MAX:
            raise NonceExhaustedError(
                f"chunk counter {chunk_counter} outside [0, 2^"
                f"{NONCE_COUNTER_BITS}) for stage {self.stage_id}: the "
                f"nonce space is spent — advance the key epoch "
                f"(KeyDirectory.advance_epoch) before the counter wraps")
        return np.array([0,
                         chunk_counter & 0xFFFFFFFF,
                         (chunk_counter >> 32) & 0xFFFFFFFF],
                        dtype=np.uint32)


def resolve_key(key, epoch: int = None) -> "StageKey":
    """Resolve a StageKey or a KeyDirectory EdgeHandle at an epoch.

    Raw StageKeys are static (epoch-less) and pass through; handles
    (repro.attest.directory.EdgeHandle, duck-typed to avoid a crypto ->
    attest import) pull the live key from the directory — ``epoch=None``
    means the edge's current epoch.  The single dispatch point for every
    sealing layer (enclave, secure_channel).
    """
    return key if isinstance(key, StageKey) else key.key(epoch)


def current_epoch(key) -> int:
    """The epoch a seal under ``key`` happens in (0 for static keys)."""
    return 0 if isinstance(key, StageKey) else key.epoch


def derive_stage_key(root: bytes, stage_name: str, stage_id: int) -> StageKey:
    h = hashlib.sha256(root + b"|" + stage_name.encode()).digest()
    key = np.frombuffer(h, dtype="<u4").copy()
    return StageKey(key=key, stage_id=stage_id)


def root_key_from_seed(seed: int) -> bytes:
    return hashlib.sha256(f"repro-root-{seed}".encode()).digest()
