"""Host-side Poly1305 (RFC 7539) with Python big ints.

Used for *sealed storage* (checkpoints written to disk), where the MAC runs
on the host CPU anyway and the 128-bit tag is worth the big-int cost.  The
TPU data path uses the CW-MAC (cwmac.py) instead — see DESIGN.md §2.
"""
from __future__ import annotations

P = (1 << 130) - 5


def _le_bytes_to_int(b: bytes) -> int:
    return int.from_bytes(b, "little")


def poly1305(key32: bytes, msg: bytes) -> bytes:
    assert len(key32) == 32
    r = _le_bytes_to_int(key32[:16])
    r &= 0x0FFFFFFC0FFFFFFC0FFFFFFC0FFFFFFF  # clamp
    s = _le_bytes_to_int(key32[16:])
    acc = 0
    for i in range(0, len(msg), 16):
        block = msg[i:i + 16]
        n = _le_bytes_to_int(block + b"\x01")
        acc = ((acc + n) * r) % P
    acc = (acc + s) % (1 << 128)
    return acc.to_bytes(16, "little")


def poly1305_verify(key32: bytes, msg: bytes, tag: bytes) -> bool:
    import hmac
    return hmac.compare_digest(poly1305(key32, msg), tag)
