"""Synthetic datasets.

``flight_records``: the paper's DelayedFlights workload (§5.2) — records of
(carrier, delay_minutes, ...) packed as 16 uint32 words each (one ChaCha20
block per record, so enclave ops are record-aligned).  The real dataset is
28M rows / 2.73 GB; the generator is deterministic per seed and scales.

``token_stream``: deterministic token shards for LM training examples —
each shard optionally AEAD-sealed at rest (the secure input pipeline).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

RECORD_WORDS = 16  # one cipher block per record
CARRIER_WORD = 0
DELAY_WORD = 1
DISTANCE_WORD = 2


def flight_records(n_records: int, num_carriers: int = 20,
                   seed: int = 0) -> np.ndarray:
    """(n_records, 16) uint32 packed records."""
    rng = np.random.default_rng(seed)
    rec = np.zeros((n_records, RECORD_WORDS), dtype=np.uint32)
    rec[:, CARRIER_WORD] = rng.integers(0, num_carriers, n_records)
    # delay minutes: mixture of on-time (<=15) and delayed (heavy tail)
    delayed = rng.random(n_records) < 0.35
    delay = np.where(delayed,
                     rng.gamma(2.0, 30.0, n_records),
                     rng.uniform(0, 15, n_records)).astype(np.uint32)
    rec[:, DELAY_WORD] = delay
    rec[:, DISTANCE_WORD] = rng.integers(100, 5000, n_records)
    rec[:, 3] = rng.integers(0, 2 ** 31, n_records)  # opaque payload
    return rec


def flight_chunks(n_records: int, chunk_records: int, num_carriers: int = 20,
                  seed: int = 0) -> Iterator[np.ndarray]:
    data = flight_records(n_records, num_carriers, seed)
    for i in range(0, n_records - chunk_records + 1, chunk_records):
        yield data[i:i + chunk_records]


def token_stream(vocab: int, seq_len: int, batch: int, n_batches: int,
                 seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Deterministic (tokens, labels) batches (labels = next token)."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        toks = rng.integers(0, vocab, (batch, seq_len + 1), dtype=np.int32)
        yield toks[:, :-1], toks[:, 1:]
