"""repro.dist — the distribution subsystem.

The paper scales its secure-stream pipelines across workers connected by
encrypted channels (§4-5, Fig. 7/8).  TPU-natively that splits into three
concerns, one module each:

* :mod:`repro.dist.meshctx`            — mesh + logical-axis sharding rules
  (``MeshContext``), the object every model/optimizer/serving layer takes;
* :mod:`repro.dist.collectives`        — secure sharded collectives: the
  ZeroMQ shuffler as an (optionally AEAD-sealed) ``all_to_all``;
* :mod:`repro.dist.pipeline_parallel`  — GPipe-style microbatch schedule
  whose stage boundaries are sealed with the ChaCha20/CW-MAC channel.

``repro.dist.compat`` papers over jax version differences (``shard_map``
moved out of ``jax.experimental`` and renamed ``check_rep``->``check_vma``).
"""
from repro.dist.meshctx import MeshContext, local_mesh_context  # noqa: F401
