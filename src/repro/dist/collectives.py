"""Secure sharded collectives: the ZeroMQ shuffler as encrypted all_to_all.

The paper's map->reduce boundary is a keyed shuffle over TLS links between
workers.  On a mesh the workers are shards of an axis and the shuffle is
one ``all_to_all``; the TLS link becomes an AEAD seal applied *before* the
collective, so the ICI/DCN wire only ever carries ChaCha20 ciphertext and
CW-MAC tags, and each destination shard verifies every block it receives.

Layout convention ("mailbox"): a routed tensor has shape (W, W, ...) with
``x[i, j]`` the sub-block worker i sends to worker j; :func:`exchange`
returns the inbox view ``y[j, i] = x[i, j]``.  Nonces are derived from
``(step, src, dst)`` so no (key, nonce) pair is ever reused across shards
or rounds.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.crypto import aead
from repro.crypto.keys import StageKey
from repro.dist.compat import shard_map

U32 = jnp.uint32


def _route_nonces(W: int, step: int) -> jax.Array:
    """(W*W, 3) nonces for the (src, dst) routing counters of one round.

    Counter ``(step*W + src)*W + dst`` is unique per (key, step, src, dst),
    so no nonce is ever reused across shards or rounds.  Computed host-side
    (numpy): seal/open run eagerly, mirroring the enclave executor — only
    the all_to_all itself is a compiled program, and it touches ciphertext
    exclusively.
    """
    src, dst = np.meshgrid(np.arange(W, dtype=np.uint64),
                           np.arange(W, dtype=np.uint64), indexing="ij")
    # all-uint64 arithmetic: mixing np.uint64 scalars with Python ints
    # promotes to float64 under NumPy 1.x value-based casting
    W64 = np.uint64(W)
    c = (np.uint64(step) * W64 + src) * W64 + dst
    return jnp.asarray(np.stack([np.zeros_like(c),
                                 c & np.uint64(0xFFFFFFFF),
                                 c >> np.uint64(32)],
                                axis=-1).reshape(W * W, 3).astype(np.uint32))


def _mailbox_spec(ndim: int, axis: str) -> P:
    return P(axis, *([None] * (ndim - 1)))


def _check_mailbox(x: jax.Array, W: int) -> None:
    if x.ndim < 2 or x.shape[0] != W or x.shape[1] != W:
        raise ValueError(
            f"mailbox layout requires shape (W, W, ...) with W={W}; "
            f"got {x.shape}")


def exchange(x: jax.Array, mesh, axis: str = "model") -> jax.Array:
    """Plain all_to_all of mailbox blocks: ``y[j, i] = x[i, j]``."""
    W = int(mesh.shape[axis])
    _check_mailbox(x, W)
    spec = _mailbox_spec(x.ndim, axis)

    def block(xb):  # local (1, W, ...)
        return jax.lax.all_to_all(xb[0], axis, 0, 0, tiled=True)[None]

    return shard_map(block, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_vma=False)(x)


def secure_exchange(x: jax.Array, mesh, axis: str = "model", *,
                    key: StageKey, step: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """AEAD-sealed all_to_all: ciphertext + tags cross the wire.

    Each (src=i, dst=j) sub-block is sealed under ``key`` with counter
    ``(step*W + i)*W + j`` before the collective and opened (MAC-checked)
    on the destination shard.  ``step`` is *required* and must be unique
    per (key, round) — reusing it reuses every (key, nonce) pair, i.e.
    a two-time pad.  ``x`` must be a 4-byte dtype (words are a same-width
    bitcast).  Returns ``(y, ok)`` with ``y[j, i]`` the opened block
    worker j received from i and ``ok[j, i]`` its MAC verdict.

    Seal/open execute eagerly shard-side (the enclave-executor idiom —
    jitting ChaCha20 costs minutes of XLA compile for zero reuse); the
    compiled collective program only ever sees ciphertext, which is the
    security boundary that matters.
    """
    if step is None:
        raise ValueError(
            "secure_exchange requires an explicit per-round step: reusing "
            "a (key, step) pair reuses the ChaCha20 keystream")
    W = int(mesh.shape[axis])
    _check_mailbox(x, W)
    if x.dtype.itemsize != 4:
        raise ValueError(f"secure_exchange needs a 4-byte dtype, got {x.dtype}")
    blk_shape = x.shape[2:]
    n_words = math.prod(blk_shape) if blk_shape else 1
    kw = jnp.asarray(key.key)

    flat = x.reshape(W * W, n_words)
    words = flat if x.dtype == jnp.uint32 else \
        jax.lax.bitcast_convert_type(flat, jnp.uint32)
    nonces = _route_nonces(W, step)                       # (W*W, 3) [src, dst]
    ct, tags = jax.vmap(aead.seal, in_axes=(None, 0, 0))(kw, nonces, words)

    # only ciphertext and tags cross the wire
    ct_r = exchange(ct.reshape(W, W, n_words), mesh, axis)
    tag_r = exchange(tags.reshape(W, W, 2), mesh, axis)

    # inbox[dst, src] was sealed with the (src, dst) counter
    nonces_in = nonces.reshape(W, W, 3).swapaxes(0, 1).reshape(W * W, 3)
    pt, ok = jax.vmap(aead.open_, in_axes=(None, 0, 0, 0))(
        kw, nonces_in, ct_r.reshape(W * W, n_words),
        tag_r.reshape(W * W, 2))
    out = pt if x.dtype == jnp.uint32 else \
        jax.lax.bitcast_convert_type(pt, x.dtype)
    return out.reshape(W, W, *blk_shape), ok.reshape(W, W)


def _consistent_hash(k: jax.Array) -> jax.Array:
    """Cheap integer mix (Knuth multiplicative) for consistent routing."""
    k = k.astype(U32) * U32(0x9E3779B1)
    return k ^ (k >> U32(16))


def keyed_route(x: jax.Array, row_keys: jax.Array, mesh,
                axis: str = "model", *, key: Optional[StageKey] = None,
                step: Optional[int] = None, hash_keys: bool = True):
    """The router's ``keyed`` policy as a sharded collective.

    ``x``: (W, n, ...) rows resident shard-wise on ``axis``; ``row_keys``:
    (W, n) integer keys.  Each shard buckets its rows by
    ``hash(key) % W`` (dense, via :func:`repro.core.router.shuffle_by_key`)
    and the buckets cross the mesh through :func:`exchange` — or
    :func:`secure_exchange` when ``key`` is given (``step`` then required,
    unique per round), in which case the wire carries only ciphertext:
    the per-bucket row counts ride *inside* the sealed payload so even
    the key-distribution metadata stays hidden.

    Returns ``(inbox, counts, ok)``: ``inbox[j, i]`` = (cap, ...) bucket
    worker j received from i, ``counts[j, i]`` its valid-row count, and
    ``ok`` the per-block MAC verdicts (all-true when unsealed).
    """
    from repro.core.router import shuffle_by_key  # lazy: router imports us

    W = int(mesh.shape[axis])
    if x.shape[0] != W or row_keys.shape[:2] != x.shape[:2]:
        raise ValueError(f"expected x (W={W}, n, ...) and matching keys; "
                         f"got {x.shape} / {row_keys.shape}")

    # shard-local bucketing (eager vmap over the worker dim — on a real
    # mesh this is each shard's local prologue; only the exchange below
    # is a collective program)
    def bucket(xb, kb):  # (n, ...), (n,)
        dest = _consistent_hash(kb) if hash_keys else kb.astype(U32)
        dest = (dest % U32(W)).astype(jnp.int32)
        return shuffle_by_key(xb, dest, W)

    mailbox, counts = jax.vmap(bucket)(x, row_keys)  # (W,W,cap,...), (W,W)

    if key is None:
        inbox = exchange(mailbox, mesh, axis)
        counts_in = exchange(counts[..., None].astype(jnp.int32), mesh,
                             axis)[..., 0]
        return inbox, counts_in, jnp.ones((W, W), bool)

    # sealed path: pack each bucket and its row count into ONE payload so
    # a single (key, step, src, dst) counter covers both — nothing about
    # the key distribution crosses the wire in cleartext.
    if x.dtype.itemsize != 4:
        raise ValueError(f"keyed_route needs a 4-byte dtype, got {x.dtype}")
    data = mailbox.reshape(W, W, -1)
    data_words = data if x.dtype == jnp.uint32 else \
        jax.lax.bitcast_convert_type(data, jnp.uint32)
    payload = jnp.concatenate(
        [data_words, counts[..., None].astype(jnp.uint32)], axis=-1)
    inbox_words, ok = secure_exchange(payload, mesh, axis, key=key, step=step)
    counts_in = inbox_words[..., -1].astype(jnp.int32)
    dw = inbox_words[..., :-1]
    inbox = (dw if x.dtype == jnp.uint32 else
             jax.lax.bitcast_convert_type(dw, x.dtype)
             ).reshape(mailbox.shape)
    return inbox, counts_in, ok
