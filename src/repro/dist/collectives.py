"""Secure sharded collectives: the ZeroMQ shuffler as encrypted all_to_all.

The paper's map->reduce boundary is a keyed shuffle over TLS links between
workers.  On a mesh the workers are shards of an axis and the shuffle is
one ``all_to_all``; the TLS link becomes an AEAD seal applied *before* the
collective, so the ICI/DCN wire only ever carries ChaCha20 ciphertext and
CW-MAC tags, and each destination shard verifies every block it receives.

Layout convention ("mailbox"): a routed tensor has shape (W, W, ...) with
``x[i, j]`` the sub-block worker i sends to worker j; :func:`exchange`
returns the inbox view ``y[j, i] = x[i, j]``.  Nonces are derived from
``(step, src, dst)`` so no (key, nonce) pair is ever reused across shards
or rounds.
"""
from __future__ import annotations

import functools
import math
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.crypto import aead
from repro.crypto.keys import StageKey
from repro.dist.compat import shard_map
from repro.obs.metrics import REGISTRY as _METRICS
from repro.obs.trace import NULL_TRACER

U32 = jnp.uint32

_NONCE_CACHE: "OrderedDict[Tuple[int, int], jax.Array]" = OrderedDict()
_NONCE_CACHE_MAX = 32


@functools.lru_cache(maxsize=8)
def _route_counter_base(W: int) -> np.ndarray:
    """(W*W,) uint64 ``src*W + dst`` grid — the step-independent part."""
    src, dst = np.meshgrid(np.arange(W, dtype=np.uint64),
                           np.arange(W, dtype=np.uint64), indexing="ij")
    # all-uint64 arithmetic: mixing np.uint64 scalars with Python ints
    # promotes to float64 under NumPy 1.x value-based casting
    return (src * np.uint64(W) + dst).reshape(-1)


def _route_nonces_base(W: int, base: int) -> jax.Array:
    """(W*W, 3) nonces for counters ``base + src*W + dst`` of one round.

    Each counter is unique per (key, base, src, dst) as long as the caller
    reserves the whole [base, base + W²) block — no nonce reuse across
    shards or rounds.  The host-side numpy grid is cached per W (and the
    final device array per (W, base)), so repeated rounds pay no
    reconstruction cost.
    """
    ck = (W, int(base))
    hit = _NONCE_CACHE.get(ck)
    if hit is not None:
        _NONCE_CACHE.move_to_end(ck)
        return hit
    c = np.uint64(base) + _route_counter_base(W)
    out = jnp.asarray(np.stack([np.zeros_like(c),
                                c & np.uint64(0xFFFFFFFF),
                                c >> np.uint64(32)],
                               axis=-1).astype(np.uint32))
    _NONCE_CACHE[ck] = out
    while len(_NONCE_CACHE) > _NONCE_CACHE_MAX:
        _NONCE_CACHE.popitem(last=False)
    return out


def _route_nonces(W: int, step: int) -> jax.Array:
    """Legacy step addressing: round ``step`` covers counters
    ``(step*W + src)*W + dst`` — i.e. base ``step * W²``."""
    return _route_nonces_base(W, step * W * W)


def _mailbox_spec(ndim: int, axis: str) -> P:
    return P(axis, *([None] * (ndim - 1)))


def _check_mailbox(x: jax.Array, W: int) -> None:
    if x.ndim < 2 or x.shape[0] != W or x.shape[1] != W:
        raise ValueError(
            f"mailbox layout requires shape (W, W, ...) with W={W}; "
            f"got {x.shape}")


_EXCHANGE_CALLS = _METRICS.counter("dist.exchange_calls")
# one eager exchange() == one launched collective program; counted here,
# next to the legacy per-site counter (never inside the shard_map body)
_DISPATCHES = _METRICS.counter("device.dispatches")
_DISP_EXCHANGE = _METRICS.counter("device.dispatches.dist.exchange")


def exchange_call_count() -> int:
    """Total :func:`exchange` collectives issued (tests/benchmarks assert
    the sealed path costs exactly ONE collective per round).  Shim over
    the registered counter ``dist.exchange_calls``."""
    return int(_EXCHANGE_CALLS.value)


def exchange(x: jax.Array, mesh, axis: str = "model", *,
             tracer=NULL_TRACER) -> jax.Array:
    """Plain all_to_all of mailbox blocks: ``y[j, i] = x[i, j]``."""
    _EXCHANGE_CALLS.inc()
    _DISPATCHES.inc()
    _DISP_EXCHANGE.inc()
    W = int(mesh.shape[axis])
    _check_mailbox(x, W)
    spec = _mailbox_spec(x.ndim, axis)

    def block(xb):  # local (1, W, ...)
        return jax.lax.all_to_all(xb[0], axis, 0, 0, tiled=True)[None]

    with tracer.span("dist.exchange", cat="dispatch", track="dist",
                     W=W, shape=str(tuple(x.shape))):
        return shard_map(block, mesh=mesh, in_specs=spec, out_specs=spec,
                         check_vma=False)(x)


def _resolve_session(key, step: Optional[int],
                     n_counters: int) -> Tuple[StageKey, int]:
    """Resolve (key, base counter) for a round that seals ``n_counters``
    blocks, from a raw StageKey or a KeyDirectory handle.

    With an ``EdgeHandle`` (repro.attest.directory) the key is the edge's
    current-epoch session key and the WHOLE ``n_counters`` block is
    reserved from the directory's per-edge chunk counter — so other
    consumers of the same edge (e.g. ``SecureChannel.protect``) can never
    land inside this round's nonce range, and an epoch rotation resets
    the counter before exhaustion.  An explicit ``step`` is rejected for
    handles: it would bypass the managed counter and collide with a later
    managed allocation (a two-time pad).  A raw StageKey keeps the legacy
    contract: ``step`` is required, addresses a disjoint ``n_counters``-
    sized block per round, and uniqueness is the caller's burden.
    """
    if key is not None and not isinstance(key, StageKey):
        if step is not None:
            raise ValueError(
                "a KeyDirectory edge handle manages its own round "
                "counters; passing an explicit step would collide with a "
                "later managed allocation of the same value (nonce reuse)")
        return key.key(), key.next_counters(n_counters)
    if step is None:
        raise ValueError(
            "secure_exchange requires an explicit per-round step: reusing "
            "a (key, step) pair reuses the ChaCha20 keystream (pass a "
            "KeyDirectory edge handle to get managed counters)")
    return key, step * n_counters


def secure_exchange(x: jax.Array, mesh, axis: str = "model", *,
                    key, step: Optional[int] = None, tracer=NULL_TRACER
                    ) -> Tuple[jax.Array, jax.Array]:
    """AEAD-sealed all_to_all: ciphertext + tags cross the wire.

    ``key`` is a KeyDirectory edge handle (preferred — current-epoch
    session key + managed round counters) or a raw StageKey, in which
    case ``step`` is *required* and must be unique per (key, round) —
    reusing it reuses every (key, nonce) pair, i.e. a two-time pad.

    Each (src=i, dst=j) sub-block is sealed with counter
    ``(step*W + i)*W + j`` before the collective and opened (MAC-checked)
    on the destination shard.  ``x`` must be a 4-byte dtype (words are a
    same-width bitcast).  Returns ``(y, ok)`` with ``y[j, i]`` the opened
    block worker j received from i and ``ok[j, i]`` its MAC verdict.

    All W² blocks are sealed by ONE compiled :func:`repro.crypto.aead.
    seal_many` program (shape-keyed compile cache: every round reuses the
    same (W², n_words) signature, so the compile amortizes across rounds),
    and the ciphertext + tags are packed into a single sealed payload so
    each round issues exactly ONE :func:`exchange` collective.  The wire
    still only ever carries ciphertext and MAC tags.
    """
    W = int(mesh.shape[axis])
    key, base = _resolve_session(key, step, W * W)
    _check_mailbox(x, W)
    if x.dtype.itemsize != 4:
        raise ValueError(f"secure_exchange needs a 4-byte dtype, got {x.dtype}")
    blk_shape = x.shape[2:]
    n_words = math.prod(blk_shape) if blk_shape else 1
    kw = jnp.asarray(key.key)

    with tracer.span("dist.secure_exchange", cat="dispatch", track="dist",
                     W=W, n_words=n_words, base_counter=int(base)):
        flat = x.reshape(W * W, n_words)
        words = flat if x.dtype == jnp.uint32 else \
            jax.lax.bitcast_convert_type(flat, jnp.uint32)
        nonces = _route_nonces_base(W, base)              # (W*W, 3) [src, dst]
        ct, tags = aead.seal_many(kw, nonces, words)      # one program

        # pack ciphertext + tags into one payload: ONE collective per round
        payload = jnp.concatenate([ct, tags],
                                  axis=-1).reshape(W, W, n_words + 2)
        payload_r = exchange(payload, mesh, axis,
                             tracer=tracer).reshape(W * W, n_words + 2)

        # inbox[dst, src] was sealed with the (src, dst) counter
        nonces_in = nonces.reshape(W, W, 3).swapaxes(0, 1).reshape(W * W, 3)
        pt, ok = aead.open_many(kw, nonces_in, payload_r[:, :n_words],
                                payload_r[:, n_words:])
        out = pt if x.dtype == jnp.uint32 else \
            jax.lax.bitcast_convert_type(pt, x.dtype)
        return out.reshape(W, W, *blk_shape), ok.reshape(W, W)


def _consistent_hash(k: jax.Array) -> jax.Array:
    """Cheap integer mix (Knuth multiplicative) for consistent routing."""
    k = k.astype(U32) * U32(0x9E3779B1)
    return k ^ (k >> U32(16))


def keyed_route(x: jax.Array, row_keys: jax.Array, mesh,
                axis: str = "model", *, key=None,
                step: Optional[int] = None, hash_keys: bool = True):
    """The router's ``keyed`` policy as a sharded collective.

    ``x``: (W, n, ...) rows resident shard-wise on ``axis``; ``row_keys``:
    (W, n) integer keys.  Each shard buckets its rows by
    ``hash(key) % W`` (dense, via :func:`repro.core.router.shuffle_by_key`)
    and the buckets cross the mesh through :func:`exchange` — or
    :func:`secure_exchange` when ``key`` is given (a KeyDirectory edge
    handle with managed counters, or a raw StageKey with ``step`` then
    required and unique per round), in which case the wire carries only
    ciphertext:
    the per-bucket row counts ride *inside* the sealed payload so even
    the key-distribution metadata stays hidden.

    Returns ``(inbox, counts, ok)``: ``inbox[j, i]`` = (cap, ...) bucket
    worker j received from i, ``counts[j, i]`` its valid-row count, and
    ``ok`` the per-block MAC verdicts (all-true when unsealed).
    """
    from repro.core.router import shuffle_by_key  # lazy: router imports us

    W = int(mesh.shape[axis])
    if x.shape[0] != W or row_keys.shape[:2] != x.shape[:2]:
        raise ValueError(f"expected x (W={W}, n, ...) and matching keys; "
                         f"got {x.shape} / {row_keys.shape}")

    # shard-local bucketing (eager vmap over the worker dim — on a real
    # mesh this is each shard's local prologue; only the exchange below
    # is a collective program)
    def bucket(xb, kb):  # (n, ...), (n,)
        dest = _consistent_hash(kb) if hash_keys else kb.astype(U32)
        dest = (dest % U32(W)).astype(jnp.int32)
        return shuffle_by_key(xb, dest, W)

    mailbox, counts = jax.vmap(bucket)(x, row_keys)  # (W,W,cap,...), (W,W)

    if key is None:
        inbox = exchange(mailbox, mesh, axis)
        counts_in = exchange(counts[..., None].astype(jnp.int32), mesh,
                             axis)[..., 0]
        return inbox, counts_in, jnp.ones((W, W), bool)

    # sealed path: pack each bucket and its row count into ONE payload so
    # a single (key, step, src, dst) counter covers both — nothing about
    # the key distribution crosses the wire in cleartext.
    if x.dtype.itemsize != 4:
        raise ValueError(f"keyed_route needs a 4-byte dtype, got {x.dtype}")
    data = mailbox.reshape(W, W, -1)
    data_words = data if x.dtype == jnp.uint32 else \
        jax.lax.bitcast_convert_type(data, jnp.uint32)
    payload = jnp.concatenate(
        [data_words, counts[..., None].astype(jnp.uint32)], axis=-1)
    inbox_words, ok = secure_exchange(payload, mesh, axis, key=key, step=step)
    counts_in = inbox_words[..., -1].astype(jnp.int32)
    dw = inbox_words[..., :-1]
    inbox = (dw if x.dtype == jnp.uint32 else
             jax.lax.bitcast_convert_type(dw, x.dtype)
             ).reshape(mailbox.shape)
    return inbox, counts_in, ok
