"""Version-compat shims for jax distribution APIs.

The repo writes against the modern spelling (``jax.shard_map`` with a
``check_vma=`` keyword); older installs ship
``jax.experimental.shard_map.shard_map`` with ``check_rep=`` instead.
Every repro call site routes through this module so the rest of the code
uses exactly one spelling.
"""
from __future__ import annotations

import inspect

import jax


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # jax < 0.6
    return fn


_SHARD_MAP = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` under any supported jax version.

    ``check_vma`` maps onto the old ``check_rep`` flag when needed (they
    gate the same replication/varying-manual-axes check).
    """
    kw = {}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kw["check_rep"] = check_vma
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
