"""Mesh contexts: logical-axis sharding rules resolved against a mesh.

A :class:`MeshContext` bundles a device mesh with the MaxText-style
logical-axis rules from :class:`repro.configs.base.ShardingConfig` and is
the single object the model / optimizer / serving layers take to answer
"how is this tensor laid out?".  Resolution semantics (``spec_for``):

* each logical dim maps to a tuple of candidate mesh axes, tried in order;
* axes missing from the mesh are skipped (a single-pod mesh simply ignores
  the ``pod`` axis in a ``("pod", "data")`` rule);
* eligible axes are accumulated greedily while their combined size still
  divides the dim — ``("data", "model")`` over a 16x16 mesh shards a
  256-row batch 256 ways as the tuple entry ``("data", "model")``;
* an axis is never used twice within one spec (first dim wins, later dims
  replicate);
* if no candidate divides the dim: under ``strict`` (or
  ``allow_uneven=False``) the dim replicates; otherwise the first free
  candidate is used anyway and GSPMD pads the ragged shard.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SpecEntry = Union[None, str, Tuple[str, ...]]


@dataclass
class MeshContext:
    """A mesh plus the logical-axis -> mesh-axis sharding rules.

    Deliberately *not* frozen: callers (dry-run shape overrides, tests)
    re-point ``rules`` at a per-shape variant of the base rule set.
    """

    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]]
    allow_uneven: bool = True

    # ------------------------------------------------------- introspection

    def axis_size(self, name: str) -> int:
        """Size of a mesh axis; absent axes count as 1 (unsharded)."""
        return int(self.mesh.shape.get(name, 1))

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        """The pure data-parallel axes present in this mesh."""
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    # ---------------------------------------------------------- resolution

    def spec_for(self, dims: Sequence[Optional[str]],
                 shape: Sequence[int], *, strict: bool = False) -> P:
        """Resolve logical dim names against the mesh -> ``PartitionSpec``."""
        assert len(dims) == len(shape), (tuple(dims), tuple(shape))
        used: set = set()
        parts = [self._resolve_dim(name, int(dim), used, strict)
                 for name, dim in zip(dims, shape)]
        return P(*parts)

    def _resolve_dim(self, name: Optional[str], dim: int, used: set,
                     strict: bool) -> SpecEntry:
        if name is None:
            return None
        candidates = self.rules.get(name, ())
        group: list = []
        prod = 1
        for ax in candidates:
            if ax not in self.mesh.shape or ax in used or ax in group:
                continue
            size = self.axis_size(ax)
            if dim % (prod * size) == 0:
                group.append(ax)
                prod *= size
        if not group and self.allow_uneven and not strict:
            # divisibility fallback: GSPMD pads the ragged last shard
            group = [ax for ax in candidates
                     if ax in self.mesh.shape and ax not in used][:1]
        if not group:
            return None
        used.update(group)
        return group[0] if len(group) == 1 else tuple(group)

    # --------------------------------------------------------- conveniences

    def sharding(self, dims: Sequence[Optional[str]],
                 shape: Sequence[int], *, strict: bool = False
                 ) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(dims, shape,
                                                      strict=strict))

    def constrain(self, x: jax.Array,
                  dims: Sequence[Optional[str]]) -> jax.Array:
        """``with_sharding_constraint`` by logical dim names (jit or eager)."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(dims, x.shape))


def local_mesh_context(n_devices: int = 0, rules=None,
                       allow_uneven: bool = True) -> MeshContext:
    """A smoke-mesh context over whatever devices exist (tests/examples)."""
    from repro.configs.base import ShardingConfig
    from repro.launch.mesh import make_smoke_mesh

    if rules is None:
        rules = ShardingConfig().lookup()
    return MeshContext(mesh=make_smoke_mesh(n_devices), rules=dict(rules),
                       allow_uneven=allow_uneven)
