"""GPipe-style pipeline parallelism with AEAD-sealed stage boundaries.

The paper encrypts every inter-worker stream; for model pipeline
parallelism the analogous wire is the activation crossing a stage
boundary.  ``pipeline_apply`` runs the classic GPipe schedule — S stages,
M microbatches, M+S-1 ticks, microbatch m entering stage s at tick m+s —
and seals every stage->stage hand-off with
:func:`repro.core.secure_channel.protect` / ``unprotect`` (ChaCha20-CTR +
CW-MAC), so a tampered activation is detected at the receiving stage.

This module is the *schedule* reference: stages execute in tick order in
one program, which is exact on any device count (tests run it on 1 CPU
device).  On a real ``("stage",)`` mesh the same tick loop lowers onto
:func:`repro.core.secure_channel.sealed_ppermute` — ciphertext on the ICI
wire — which shares the per-edge session keys.  Keys come from a
``repro.attest.KeyDirectory`` (:func:`edge_directory`): each stage
boundary is an attested handshake session, and ``rekey_every_n`` ratchets
every edge key mid-schedule (chunks sealed before a flip drain under
their sealing epoch).

Sealing rides the batched AEAD fast path: every stage->stage hand-off of a
tick is sealed by ONE :func:`repro.core.secure_channel.protect_many`
program (per-edge keys batched), and every sealed inflow of the next tick
is opened by one ``unprotect_many`` — the activation shapes repeat across
ticks, so the shape-keyed compile cache makes each tick a cache hit after
the first.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.attest.directory import KeyDirectory
from repro.attest.measure import measure_bytes
from repro.core.secure_channel import protect_many, unprotect_many
from repro.crypto.keys import StageKey


class PipelineMACError(RuntimeError):
    """A sealed stage-boundary activation failed its MAC check."""


def gpipe_schedule(num_stages: int,
                   num_microbatches: int) -> List[List[Tuple[int, int]]]:
    """The GPipe tick table: ``ticks[t]`` lists active ``(stage, mb)``.

    M + S - 1 ticks; microbatch m occupies stage s at tick m + s.  The
    bubble fraction is the classic (S-1)/(M+S-1).
    """
    S, M = num_stages, num_microbatches
    return [[(s, t - s) for s in range(S) if 0 <= t - s < M]
            for t in range(M + S - 1)]


def edge_directory(num_stages: int, *, seed: int = 0,
                   label: str = "pp") -> KeyDirectory:
    """A KeyDirectory with one attested session per stage boundary.

    Each stage endpoint is enrolled under a measurement of its position in
    the chain and edge ``{label}-edge{s}`` (into stage s, s >= 1) is
    established by the quote-checked handshake — the paper's "key
    establishment was previously performed", actually performed.
    """
    d = KeyDirectory(seed=seed)
    for s in range(num_stages):
        m = measure_bytes(b"pp-stage", label.encode(), str(s).encode())
        d.enroll(f"{label}/stage{s}", m, allow=True)
    for s in range(1, num_stages):
        d.establish(f"{label}-edge{s}", f"{label}/stage{s - 1}",
                    f"{label}/stage{s}", stage_id=s)
    return d


# pipeline_apply's default directories, one per (S, seed, label): the
# handshakes are a control-plane cost (~84 ms/edge) that must not recur
# on every invocation of a per-step schedule.  Callers who rekey should
# pass their own directory — epoch state on a shared default would leak
# across unrelated callers.
_DEFAULT_DIRS: dict = {}


def _default_edge_directory(num_stages: int, seed: int,
                            label: str) -> KeyDirectory:
    ck = (num_stages, seed, label)
    d = _DEFAULT_DIRS.get(ck)
    if d is None:
        d = _DEFAULT_DIRS[ck] = edge_directory(num_stages, seed=seed,
                                               label=label)
    return d


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_weights: jax.Array,
                   microbatches: jax.Array,
                   mesh: Optional[jax.sharding.Mesh] = None, *,
                   axis: str = "stage",
                   seal: bool = True,
                   key_seed: int = 0,
                   step: int = 0,
                   directory: Optional[KeyDirectory] = None,
                   rekey_every_n: Optional[int] = None,
                   key_label: str = "pp") -> jax.Array:
    """Apply an S-stage pipeline to M microbatches on the GPipe schedule.

    ``stage_weights``: (S, ...) — stage s computes
    ``stage_fn(stage_weights[s], x)``.  ``microbatches``: (M, ...) enter
    stage 0 in order; returns the (M, ...) stack of stage S-1 outputs,
    bitwise equal to sequentially chaining the stages per microbatch
    (sealing is an exact XOR-stream roundtrip).

    Edge keys come from a ``repro.attest.KeyDirectory`` (``directory``,
    or an ephemeral :func:`edge_directory` seeded by ``key_seed``), one
    attested session per boundary.  ``rekey_every_n`` ratchets every edge
    key after each N ticks, mid-schedule: a hand-off sealed in epoch E is
    opened with the epoch-E key one tick later even if the flip happened
    in between (old epoch drains, new epoch seals).

    Edge counters are ``step * M + microbatch``: a caller invoking this
    repeatedly under the same directory/seed (e.g. once per training
    step) MUST pass a distinct ``step`` each time, or every invocation
    reuses the per-edge (key, nonce) pairs — a two-time pad on the
    activations.

    When ``mesh`` carries an ``axis`` axis of size > 1 it must equal S
    (one stage per shard); the schedule itself is device-count agnostic.
    """
    S = int(stage_weights.shape[0])
    M = int(microbatches.shape[0])
    if mesh is not None and axis in mesh.shape:
        n = int(mesh.shape[axis])
        if n > 1 and n != S:
            raise ValueError(
                f"mesh axis {axis!r} has size {n} but there are {S} stages")
    d = None
    if seal and S > 1:
        d = directory if directory is not None else \
            _default_edge_directory(S, key_seed, key_label)
        if directory is None and rekey_every_n:
            raise ValueError(
                "rekey_every_n mutates the directory's epoch state; pass "
                "an explicit directory= (edge_directory(...)) instead of "
                "sharing the cached default")

    def _edge_key(s: int, epoch: Optional[int] = None) -> StageKey:
        return d.edge_key(f"{key_label}-edge{s}", epoch=epoch)

    outs: List[Optional[jax.Array]] = [None] * M
    # inflight[s]: the (sealed) activation entering stage s next tick;
    # sealed entries are (ct, tag, meta, epoch-at-seal).
    inflight: dict = {}
    for t, tick in enumerate(gpipe_schedule(S, M)):
        # open every sealed inflow of this tick in ONE batched program
        # (grouped by activation shape; shape-preserving stage_fns — the
        # common case — yield a single group per tick).  Per-item keys are
        # resolved at each entry's sealing epoch, so one batch may mix
        # epochs across a rekey boundary.
        opened: dict = {}
        if seal:
            groups: dict = {}
            for s, mb in tick:
                if s > 0:
                    ct, _, meta, _ = inflight[s]
                    groups.setdefault((ct.shape, meta), []).append((s, mb))
            for (_, meta), members in groups.items():
                cts = jnp.stack([inflight[s][0] for s, _ in members])
                tags = jnp.stack([inflight[s][1] for s, _ in members])
                xs, oks = unprotect_many(
                    [_edge_key(s, inflight[s][3]) for s, _ in members],
                    [step * M + mb for _, mb in members], cts, tags, meta)
                for i, (s, mb) in enumerate(members):
                    if not bool(oks[i]):
                        raise PipelineMACError(
                            f"MAC failure on edge into stage {s}, "
                            f"microbatch {mb}")
                    opened[s] = xs[i]

        sends: List[Tuple[int, int, jax.Array]] = []  # (stage, mb, act)
        for s, mb in tick:
            if s == 0:
                x = microbatches[mb]
            elif seal:
                x = opened[s]
            else:
                x = inflight[s]
            y = stage_fn(stage_weights[s], x)
            if s == S - 1:
                outs[mb] = y
            else:
                sends.append((s + 1, mb, y))

        # seal every hand-off of this tick in ONE batched program per
        # activation shape (one group when stage_fn preserves shape)
        nxt: dict = {}
        if seal and sends:
            out_groups: dict = {}
            for s, mb, y in sends:
                out_groups.setdefault((y.shape, str(y.dtype)),
                                      []).append((s, mb, y))
            for members in out_groups.values():
                cts, tags, meta = protect_many(
                    [_edge_key(s) for s, _, _ in members],
                    [step * M + mb for _, mb, _ in members],
                    jnp.stack([y for _, _, y in members]))
                for i, (s, _, _) in enumerate(members):
                    nxt[s] = (cts[i], tags[i], meta, d.epoch)
        else:
            for s, _, y in sends:
                nxt[s] = y
        inflight = nxt
        # epoch flip between ticks: the hand-offs sealed above keep their
        # sealing epoch and drain under it next tick
        if d is not None and rekey_every_n and (t + 1) % rekey_every_n == 0:
            d.advance_epoch()
    return jnp.stack(outs)
