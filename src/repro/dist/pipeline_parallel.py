"""GPipe-style pipeline parallelism with AEAD-sealed stage boundaries.

The paper encrypts every inter-worker stream; for model pipeline
parallelism the analogous wire is the activation crossing a stage
boundary.  ``pipeline_apply`` runs the classic GPipe schedule — S stages,
M microbatches, M+S-1 ticks, microbatch m entering stage s at tick m+s —
and seals every stage->stage hand-off with
:func:`repro.core.secure_channel.protect` / ``unprotect`` (ChaCha20-CTR +
CW-MAC), so a tampered activation is detected at the receiving stage.

This module is the *schedule* reference: stages execute in tick order in
one program, which is exact on any device count (tests run it on 1 CPU
device).  On a real ``("stage",)`` mesh the same tick loop lowers onto
:func:`repro.core.secure_channel.sealed_ppermute` — ciphertext on the ICI
wire — which shares the per-edge keys derived here.

Sealing rides the batched AEAD fast path: every stage->stage hand-off of a
tick is sealed by ONE :func:`repro.core.secure_channel.protect_many`
program (per-edge keys batched), and every sealed inflow of the next tick
is opened by one ``unprotect_many`` — the activation shapes repeat across
ticks, so the shape-keyed compile cache makes each tick a cache hit after
the first.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.secure_channel import protect_many, unprotect_many
from repro.crypto.keys import StageKey, derive_stage_key, root_key_from_seed


class PipelineMACError(RuntimeError):
    """A sealed stage-boundary activation failed its MAC check."""


def gpipe_schedule(num_stages: int,
                   num_microbatches: int) -> List[List[Tuple[int, int]]]:
    """The GPipe tick table: ``ticks[t]`` lists active ``(stage, mb)``.

    M + S - 1 ticks; microbatch m occupies stage s at tick m + s.  The
    bubble fraction is the classic (S-1)/(M+S-1).
    """
    S, M = num_stages, num_microbatches
    return [[(s, t - s) for s in range(S) if 0 <= t - s < M]
            for t in range(M + S - 1)]


def edge_keys(num_stages: int, *, seed: int = 0,
              label: str = "pp") -> List[StageKey]:
    """One session key per stage boundary; ``keys[s]`` seals the edge
    *into* stage s (``keys[0]`` is unused — stage 0 reads the source)."""
    root = root_key_from_seed(seed)
    return [derive_stage_key(root, f"{label}-edge{s}", s)
            for s in range(num_stages)]


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_weights: jax.Array,
                   microbatches: jax.Array,
                   mesh: Optional[jax.sharding.Mesh] = None, *,
                   axis: str = "stage",
                   seal: bool = True,
                   key_seed: int = 0,
                   step: int = 0) -> jax.Array:
    """Apply an S-stage pipeline to M microbatches on the GPipe schedule.

    ``stage_weights``: (S, ...) — stage s computes
    ``stage_fn(stage_weights[s], x)``.  ``microbatches``: (M, ...) enter
    stage 0 in order; returns the (M, ...) stack of stage S-1 outputs,
    bitwise equal to sequentially chaining the stages per microbatch
    (sealing is an exact XOR-stream roundtrip).

    Edge counters are ``step * M + microbatch``: a caller invoking this
    repeatedly under the same ``key_seed`` (e.g. once per training step)
    MUST pass a distinct ``step`` each time, or every invocation reuses
    the per-edge (key, nonce) pairs — a two-time pad on the activations.

    When ``mesh`` carries an ``axis`` axis of size > 1 it must equal S
    (one stage per shard); the schedule itself is device-count agnostic.
    """
    S = int(stage_weights.shape[0])
    M = int(microbatches.shape[0])
    if mesh is not None and axis in mesh.shape:
        n = int(mesh.shape[axis])
        if n > 1 and n != S:
            raise ValueError(
                f"mesh axis {axis!r} has size {n} but there are {S} stages")
    keys = edge_keys(S, seed=key_seed) if seal else None

    outs: List[Optional[jax.Array]] = [None] * M
    # inflight[s]: the (sealed) activation entering stage s next tick.
    inflight: dict = {}
    for tick in gpipe_schedule(S, M):
        # open every sealed inflow of this tick in ONE batched program
        # (grouped by activation shape; shape-preserving stage_fns — the
        # common case — yield a single group per tick)
        opened: dict = {}
        if seal:
            groups: dict = {}
            for s, mb in tick:
                if s > 0:
                    ct, _, meta = inflight[s]
                    groups.setdefault((ct.shape, meta), []).append((s, mb))
            for (_, meta), members in groups.items():
                cts = jnp.stack([inflight[s][0] for s, _ in members])
                tags = jnp.stack([inflight[s][1] for s, _ in members])
                xs, oks = unprotect_many(
                    [keys[s] for s, _ in members],
                    [step * M + mb for _, mb in members], cts, tags, meta)
                for i, (s, mb) in enumerate(members):
                    if not bool(oks[i]):
                        raise PipelineMACError(
                            f"MAC failure on edge into stage {s}, "
                            f"microbatch {mb}")
                    opened[s] = xs[i]

        sends: List[Tuple[int, int, jax.Array]] = []  # (stage, mb, act)
        for s, mb in tick:
            if s == 0:
                x = microbatches[mb]
            elif seal:
                x = opened[s]
            else:
                x = inflight[s]
            y = stage_fn(stage_weights[s], x)
            if s == S - 1:
                outs[mb] = y
            else:
                sends.append((s + 1, mb, y))

        # seal every hand-off of this tick in ONE batched program per
        # activation shape (one group when stage_fn preserves shape)
        nxt: dict = {}
        if seal and sends:
            out_groups: dict = {}
            for s, mb, y in sends:
                out_groups.setdefault((y.shape, str(y.dtype)),
                                      []).append((s, mb, y))
            for members in out_groups.values():
                cts, tags, meta = protect_many(
                    [keys[s] for s, _, _ in members],
                    [step * M + mb for _, mb, _ in members],
                    jnp.stack([y for _, _, y in members]))
                for i, (s, _, _) in enumerate(members):
                    nxt[s] = (cts[i], tags[i], meta)
        else:
            for s, _, y in sends:
                nxt[s] = y
        inflight = nxt
    return jnp.stack(outs)
