"""repro.dsl — the fluent pipeline DSL + declarative spec loader.

The paper's "implement secure processing pipelines in just few lines of
code" surface for this engine: :func:`stream` (fluent Listing-2 style),
:func:`load_spec` (declarative Listing-1 style, TOML/dict), both
compiling through :mod:`repro.dsl.compile` to the window-vectorized
:class:`repro.core.pipeline.Pipeline` with zero hot-path overhead.
See ``docs/dsl.md`` for the tutorial.
"""
from repro.dsl.builder import StreamBuilder, stream  # noqa: F401
from repro.dsl.compile import (DSLValidationError,  # noqa: F401
                               compile_pipeline)
from repro.dsl.reducers import (REDUCERS, register_reducer,  # noqa: F401
                                resolve_reducer)
from repro.dsl.spec import SpecError, load_spec, parse_toml  # noqa: F401
