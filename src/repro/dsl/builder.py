"""Fluent pipeline builder: the paper's "few lines of code" claim, typed.

The paper's usability pitch (Listing 1 + Listing 2) is that a secure
pipeline is *declared*, not assembled: named stages with worker counts and
``constraint:type==sgx``, composed with RxLua ``map/filter/reduce``.  This
module is that surface for the window-vectorized engine::

    from repro.dsl import stream

    out = (stream(source)
           .map("identity", name="sgx_mapper", workers=4, sgx=True)
           .filter("delay_filter_u32", const=15, name="sgx_filter",
                   workers=4, sgx=True)
           .reduce("carrier_delay_stats", name="reducer")
           .run(mode="enclave", rekey_every_n=1024))

Builders are immutable: every combinator returns a new
:class:`StreamBuilder` (exactly like :class:`repro.core.observable
.Observable`, whose :class:`~repro.core.observable.Op` nodes this module
reuses — the DSL and the Observable layer share one op-chain vocabulary).
``.run``/``.build`` hand the chain to :mod:`repro.dsl.compile`, which
validates eagerly, fuses adjacent fusable stages (fewer seal/open hops),
and emits a plain :class:`repro.core.pipeline.Pipeline` — the DSL adds
**zero** runtime machinery on the streaming hot path, which is why
``pipeline.dsl`` benches at parity with the hand-built engine.

``.as_observable()`` lowers the same chain onto a plaintext
:class:`~repro.core.observable.Observable` — a pure-jnp oracle with
identical per-chunk semantics, used by tests and docs.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.observable import Observable, Op, describe_ops


class StreamBuilder:
    """An immutable, lazily-compiled chain of named secure stages."""

    def __init__(self, source: Optional[Iterable] = None,
                 ops: Tuple[Op, ...] = (),
                 settings: Optional[dict] = None):
        self._source = source
        self._ops = tuple(ops)
        self._settings = dict(settings or {})
        #: the last Pipeline compiled by .build()/.run() (report access)
        self.pipeline = None

    # ------------------------------------------------------------- plumbing

    def _with(self, op: Op) -> "StreamBuilder":
        return StreamBuilder(self._source, self._ops + (op,), self._settings)

    def _with_settings(self, **kw) -> "StreamBuilder":
        return StreamBuilder(self._source, self._ops,
                             {**self._settings, **kw})

    @staticmethod
    def _stage_meta(kind: str, op, name: Optional[str], const: float,
                    workers: int, sgx: bool, n: int) -> dict:
        opname = op if isinstance(op, str) else getattr(op, "__name__", kind)
        return {"name": name or f"{kind}{n}_{opname}",
                "op": op if isinstance(op, str) else None,
                "const": const, "workers": workers, "sgx": sgx,
                "pinned": False}

    # ---------------------------------------------------------- combinators

    def map(self, op: Union[str, Callable], *, name: Optional[str] = None,
            const: float = 0.0, workers: int = 1,
            sgx: bool = True) -> "StreamBuilder":
        """Add a map stage.  ``op`` is a registered static operator name
        (runs fused in-enclave under ``mode="enclave"``) or a Python
        callable (attestable only outside the enclave — rejected eagerly
        by the compiler when ``sgx=True`` under enclave mode).  ``sgx``
        is the paper's ``constraint:type==sgx`` placement flag."""
        fn = None if isinstance(op, str) else op
        meta = self._stage_meta("map", op, name, const, workers, sgx,
                                len(self._ops))
        return self._with(Op("map", fn, meta=meta))

    def filter(self, op: Union[str, Callable], *,
               name: Optional[str] = None, const: float = 0.0,
               workers: int = 1, sgx: bool = True) -> "StreamBuilder":
        """Add a filter stage.  Filters are *dense* on this engine (the
        operator rewrites records in place — e.g. ``delay_filter_u32``
        zeroes non-delayed records); accelerator dataflow cannot drop
        rows dynamically, matching :meth:`Observable.filter` semantics."""
        fn = None if isinstance(op, str) else op
        meta = self._stage_meta("filter", op, name, const, workers, sgx,
                                len(self._ops))
        return self._with(Op("filter", fn, meta=meta))

    def reduce(self, fn: Union[str, Callable], init: Any = None, *,
               name: str = "reduce") -> "StreamBuilder":
        """Terminal reduce: folds decrypted chunks at the trusted
        subscriber (sink edge).  ``fn`` is a callable ``(acc, chunk) ->
        acc`` with ``init``, or the name of a registered reducer
        (:func:`repro.dsl.reducers.register_reducer`) so TOML specs can
        reference it declaratively."""
        meta = {"name": name, "reducer": fn if isinstance(fn, str) else None,
                "workers": 1, "sgx": True, "op": None, "const": 0.0,
                "pinned": False}
        f = None if isinstance(fn, str) else fn
        return self._with(Op("reduce", f, init=init, meta=meta))

    # ------------------------------------------------------------- settings

    def secure(self, mode: str) -> "StreamBuilder":
        """Set the wire/compute security mode (paper Fig. 6):
        ``plain`` | ``encrypted`` | ``enclave``."""
        return self._with_settings(mode=mode)

    def scale(self, stage: str, workers: int) -> "StreamBuilder":
        """Set a named stage's worker count (paper §5.5 elasticity,
        declared pre-build; a *live* rescale of a running pipeline is
        ``Pipeline.scale_stage``).  Scaling pins the stage: the fusion
        planner will not absorb an explicitly scaled stage."""
        found = False
        ops = []
        for o in self._ops:
            if o.meta.get("name") == stage:
                found = True
                meta = {**o.meta, "workers": int(workers), "pinned": True}
                ops.append(Op(o.kind, o.fn, o.init, meta))
            else:
                ops.append(o)
        if not found:
            known = [o.meta.get("name") for o in self._ops]
            raise KeyError(f"scale: no stage named {stage!r} "
                           f"(stages: {known})")
        return StreamBuilder(self._source, tuple(ops), self._settings)

    def window(self, window_chunks: int) -> "StreamBuilder":
        """Set the engine's window factor (chunks per worker per batched
        dispatch; 1 = the per-chunk oracle engine)."""
        return self._with_settings(window_chunks=int(window_chunks))

    def seed(self, seed: int) -> "StreamBuilder":
        """Set the KeyDirectory seed used when no directory is passed."""
        return self._with_settings(seed=int(seed))

    def directory(self, directory) -> "StreamBuilder":
        """Use an existing :class:`repro.attest.KeyDirectory` (shared
        trust domain: sessions, epoch, and revocations carry over)."""
        return self._with_settings(directory=directory)

    def fuse(self, enabled: bool = True) -> "StreamBuilder":
        """Enable/disable stage fusion (default on; fusion is only
        applied where it is bit-exact, see :mod:`repro.dsl.compile`)."""
        return self._with_settings(fuse=bool(enabled))

    def trace(self, tracer=None) -> "StreamBuilder":
        """Attach a :class:`repro.obs.trace.Tracer` to the compiled
        pipeline (a fresh one when ``tracer`` is None).  Per-window spans
        — ingress seals, per-worker open->op->seal, verdict syncs, merges,
        reduce folds — land on it; export with
        ``builder.tracer.export_chrome("trace.json")`` after a run.
        Tracing stays strictly off (zero-cost no-ops) unless this is
        called or a tracer is passed to ``Pipeline.run``."""
        from repro.obs.trace import Tracer
        return self._with_settings(
            tracer=tracer if tracer is not None else Tracer())

    @property
    def tracer(self):
        """The tracer attached via :meth:`trace` (None when untraced)."""
        return self._settings.get("tracer")

    def monitor(self, monitor=None) -> "StreamBuilder":
        """Attach a :class:`repro.obs.monitor.PipelineMonitor` (a fresh
        one when ``monitor`` is None) to the compiled pipeline.  Sliding
        per-stage health (windows/s, MB/s, p50/p95 latency, queue depth,
        worker skew, mac-failure rate, epoch lag) updates once per
        window while :meth:`run` streams; read it live via
        ``builder.health_monitor.snapshot()`` or serve it with
        ``repro.obs.export.serve_metrics``.  Monitoring stays strictly
        off (zero-cost no-ops) unless this is called or a monitor is
        passed to ``Pipeline.run``."""
        from repro.obs.monitor import PipelineMonitor
        return self._with_settings(
            monitor=monitor if monitor is not None else PipelineMonitor())

    @property
    def health_monitor(self):
        """The monitor attached via :meth:`monitor` (None when
        unmonitored)."""
        return self._settings.get("monitor")

    def retry(self, policy=None) -> "StreamBuilder":
        """Attach a :class:`repro.ft.retry.RetryPolicy` (the default
        policy when ``policy`` is None): per-share retry with bounded
        exponential backoff, failover to survivors (or a live-enrolled
        spare), speculative backup dispatch against stragglers, and
        replay of MAC-failed rows from the retained ingress window —
        every re-execution re-sealed under fresh directory-reserved
        counters, so recovery never reuses a (key, nonce, counter)
        triple and output stays bit-identical.  Requires the window
        engine (``window_chunks >= 2``)."""
        from repro.ft.retry import RetryPolicy
        return self._with_settings(
            retry=policy if policy is not None else RetryPolicy())

    @property
    def retry_policy(self):
        """The policy attached via :meth:`retry` (None when FT is off)."""
        return self._settings.get("retry")

    def chaos(self, plan) -> "StreamBuilder":
        """Attach a :class:`repro.ft.chaos.ChaosPlan`: seeded fault
        injection (worker crashes, stalls, tampered shares, dropped
        verdict syncs, enrollment failures) consulted at every engine
        hook point.  Implies :meth:`retry` with the default policy if no
        policy was attached.  Faults are deterministic per plan — the
        chaos harness's replayability contract."""
        return self._with_settings(chaos=plan)

    @property
    def chaos_plan(self):
        """The plan attached via :meth:`chaos` (None when chaos is off)."""
        return self._settings.get("chaos")

    # ------------------------------------------------------------ lowering

    def build(self, mode: Optional[str] = None, *,
              rekey_every_n: Optional[int] = None):
        """Validate + fuse + compile the chain to a
        :class:`repro.core.pipeline.Pipeline` (stored as
        ``self.pipeline``).  ``rekey_every_n`` here is only used for the
        eager rekey-vs-epoch-history check; pass it to
        :meth:`Pipeline.run` (or :meth:`run`) to actually rotate."""
        from repro.dsl.compile import compile_pipeline
        s = self._settings
        if rekey_every_n is None:
            rekey_every_n = s.get("rekey_every_n")   # spec-declared cadence
        self.pipeline = compile_pipeline(
            self._ops,
            mode=mode or s.get("mode", "enclave"),
            seed=s.get("seed", 0),
            directory=s.get("directory"),
            window_chunks=s.get("window_chunks", 8),
            fuse=s.get("fuse", True),
            rekey_every_n=rekey_every_n,
            tracer=s.get("tracer"),
            monitor=s.get("monitor"),
            retry=s.get("retry"),
            chaos=s.get("chaos"))
        return self.pipeline

    def run(self, source: Optional[Iterable] = None, *,
            mode: Optional[str] = None, on_result: Optional[Callable] = None,
            rekey_every_n: Optional[int] = None,
            window_chunks: Optional[int] = None) -> Any:
        """Compile and stream: returns the terminal reduce value (or the
        last chunk for reduce-less chains).  The source may come from
        ``stream(source)`` or be passed here; chunks are coerced with
        ``jnp.asarray`` so plain numpy iterators work."""
        src = source if source is not None else self._source
        if src is None:
            raise ValueError("no source: pass one to stream(...) or run(...)")
        if rekey_every_n is None:
            rekey_every_n = self._settings.get("rekey_every_n")
        p = self.build(mode, rekey_every_n=rekey_every_n)
        return p.run((jnp.asarray(c) for c in src), on_result=on_result,
                     rekey_every_n=rekey_every_n,
                     window_chunks=window_chunks)

    def report(self) -> dict:
        """Per-stage metrics of the last compiled pipeline — including
        the ``fused_from`` / ``fusion`` entries recording what the
        compiler merged (see ``Pipeline.report``)."""
        if self.pipeline is None:
            raise RuntimeError("nothing compiled yet — call run()/build()")
        return self.pipeline.report()

    # --------------------------------------------------------- introspection

    def describe(self) -> str:
        """One-line chain summary, same format as
        :meth:`Observable.describe` (shared op vocabulary)."""
        return describe_ops(self._ops)

    @property
    def ops(self) -> Tuple[Op, ...]:
        return self._ops

    def as_observable(self, source: Optional[Iterable] = None) -> Observable:
        """Lower the chain onto a plaintext :class:`Observable`: each
        static stage becomes a pure-jnp map with the same record
        semantics as the secure engine (dense filters included), custom
        fns pass through, the terminal reduce folds in stream order.
        Bit-identical to ``mode="plain"`` — the DSL's cleartext oracle.
        """
        from repro.core.enclave import _apply_static_f32
        from repro.dsl.reducers import resolve_reducer
        src = source if source is not None else self._source
        if src is None:
            raise ValueError("as_observable needs a source")
        obs = Observable.from_chunks(src)
        for o in self._ops:
            if o.kind in ("map", "filter"):
                if o.fn is not None:
                    obs = obs.map(o.fn)
                else:
                    op, const = o.meta["op"], o.meta["const"]
                    obs = obs.map(
                        lambda c, _op=op, _k=const: _apply_static_f32(
                            _op, _k, c))
            elif o.kind == "reduce":
                fn, init = (o.fn, o.init) if o.fn is not None \
                    else resolve_reducer(o.meta["reducer"])
                obs = obs.reduce(lambda acc, c, m, _f=fn: _f(acc, c),
                                 init=init)
        return obs


def stream(source: Optional[Iterable] = None) -> StreamBuilder:
    """Entry point of the fluent DSL: ``stream(chunks).map(...).run()``.
    ``source`` is any iterable of same-shape tensors/arrays (may also be
    supplied later to :meth:`StreamBuilder.run`)."""
    return StreamBuilder(source)
