"""DSL compiler: validate eagerly, fuse where bit-exact, emit a Pipeline.

The builder (:mod:`repro.dsl.builder`) and the spec loader
(:mod:`repro.dsl.spec`) both land here.  Three jobs:

**Eager validation** — everything the engine would only discover
mid-stream is rejected at compile time, before any data is sealed:
unknown static operator names (with the registry listed), Python
closures placed ``sgx=True`` under ``mode="enclave"`` (the paper's
no-dynamic-linking rule — the engine raises this lazily per window; the
DSL raises it before the first chunk), duplicate stage names, non-positive
worker counts, unresolvable named reducers, and ``rekey_every_n``
cadences that even the per-chunk oracle engine could not drain within the
directory's ``epoch_history`` (the same up-front rejection
``Pipeline.run`` performs, surfaced at build).

**Fusion** — adjacent ``map``/``filter`` stages are merged into a single
stage when the op registry guarantees the composition is *bit-exact*.
Today that means identity absorption: ``identity`` is an exact u32
passthrough in every mode, so ``identity ∘ f == f`` to the bit and the
absorbed stage's seal/open hop disappears.  Float compositions
(``scale_f32 ∘ scale_f32`` etc.) are deliberately NOT fused —
``(x·a)·b != x·(a·b)`` under f32 rounding, and the DSL's contract is
bit-identity with the unfused hand-built pipeline.  Every decision,
taken or declined, is recorded and surfaces in ``Pipeline.report()``
(``fusion`` entry + per-stage ``fused_from``).  Stages pinned by
``.scale()`` or carrying an explicit worker pool (``workers > 1``) are
never absorbed — fusion must not discard declared fan-out.

**Emission** — the output is a plain :class:`repro.core.pipeline
.Pipeline`; the DSL contributes nothing to the streaming hot path.
"""
from __future__ import annotations

import copy
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import SecureStreamConfig
from repro.core.observable import Op
from repro.core.pipeline import Pipeline, Stage
from repro.kernels.enclave_map.ops import OPS

MODES = ("plain", "encrypted", "enclave")


class DSLValidationError(ValueError):
    """A pipeline description rejected at compile time (build, not run)."""


# ------------------------------------------------------------------ validate


def _stage_dicts(ops: Sequence[Op]) -> List[dict]:
    """Normalize builder Ops into flat stage descriptors."""
    out = []
    for o in ops:
        d = dict(o.meta)
        d["kind"] = o.kind
        d["fn"] = o.fn
        d["init"] = o.init
        out.append(d)
    return out


def validate(ops: Sequence[Op], mode: str) -> List[dict]:
    """Eager structural validation; returns normalized stage dicts."""
    if mode not in MODES:
        raise DSLValidationError(
            f"unknown mode {mode!r}; expected one of {MODES}")
    stages = _stage_dicts(ops)
    if not stages:
        raise DSLValidationError("empty pipeline: add map/filter/reduce "
                                 "stages before build()/run()")
    reduces = [i for i, s in enumerate(stages) if s["kind"] == "reduce"]
    if len(reduces) > 1:
        raise DSLValidationError("at most one reduce stage (it is terminal)")
    if reduces and reduces[0] != len(stages) - 1:
        raise DSLValidationError(
            f"reduce must be the terminal stage, found it at position "
            f"{reduces[0]} of {len(stages)}")
    seen = set()
    for s in stages:
        name = s["name"]
        if name in seen:
            raise DSLValidationError(
                f"duplicate stage name {name!r} — stage names are worker-id "
                f"prefixes and must be unique")
        seen.add(name)
        if int(s["workers"]) < 1:
            raise DSLValidationError(
                f"stage {name!r}: workers must be >= 1, got {s['workers']}")
        if s["kind"] == "reduce":
            if s["fn"] is None:
                from repro.dsl.reducers import resolve_reducer
                resolve_reducer(s["reducer"])       # raises with known names
            continue
        if s["fn"] is None:
            if s["op"] not in OPS:
                raise DSLValidationError(
                    f"stage {name!r}: unknown static op {s['op']!r}; "
                    f"registered ops: {sorted(OPS)}")
        elif mode == "enclave" and s["sgx"]:
            raise DSLValidationError(
                f"stage {name!r}: a Python closure cannot run sgx=True "
                f"under mode='enclave' — only registered static operators "
                f"are attestable (the paper's no-dynamic-linking rule). "
                f"Use a registry op, or mark the stage sgx=False to run "
                f"it on the encrypted (non-enclave) path.")
    return stages


# ------------------------------------------------------------------- fusion


def _is_identity(s: dict) -> bool:
    return s["kind"] in ("map", "filter") and s["fn"] is None \
        and s["op"] == "identity"


def _absorbable(s: dict) -> bool:
    # an explicitly requested worker pool is part of the declared
    # topology — absorbing the stage would silently discard its fan-out
    return _is_identity(s) and not s.get("pinned") \
        and int(s["workers"]) == 1


_F32_OPS = ("scale_f32", "relu_f32", "square_f32", "threshold_mask")


def plan_fusion(stages: List[dict], enabled: bool
                ) -> Tuple[List[dict], Dict[str, List[str]], List[str]]:
    """-> (surviving stages, {survivor: [absorbed...]}, decision log).

    Only bit-exact merges are taken (identity absorption); everything
    considered is logged either way so ``report()`` shows the plan.
    """
    decisions: List[str] = []
    fused_from: Dict[str, List[str]] = {}
    prefix = [s for s in stages if s["kind"] != "reduce"]
    tail = [s for s in stages if s["kind"] == "reduce"]
    if not enabled:
        if len(prefix) > 1:
            decisions.append("fusion disabled (.fuse(False))")
        return stages, fused_from, decisions

    for s in prefix:
        if _is_identity(s) and s.get("pinned"):
            decisions.append(
                f"kept '{s['name']}': identity stage pinned by .scale()")
        elif _is_identity(s) and int(s["workers"]) > 1:
            decisions.append(
                f"kept '{s['name']}': identity stage has a worker pool "
                f"(workers={s['workers']}) — absorbing it would discard "
                f"the declared fan-out")

    survivors: List[dict] = []
    pending: List[str] = []
    for s in prefix:
        if _absorbable(s):
            pending.append(s["name"])
            continue
        if pending:
            fused_from.setdefault(s["name"], []).extend(pending)
            pending = []
        survivors.append(s)
    if pending:                       # trailing identities, or all-identity
        if survivors:
            fused_from.setdefault(survivors[-1]["name"], []).extend(pending)
        else:
            last = next(s for s in reversed(prefix)
                        if s["name"] == pending[-1])
            survivors.append(last)
            if pending[:-1]:
                fused_from[last["name"]] = pending[:-1]

    for host, absorbed in fused_from.items():
        decisions.append(
            f"fused {absorbed} into '{host}': identity is an exact u32 "
            f"passthrough (identity∘f == f bit-exact; "
            f"{len(absorbed)} seal/open hop(s) removed)")
    for a, b in zip(survivors, survivors[1:]):
        # identity survivors were already logged above with their real
        # keep-reason (pinned / worker pool) — identity∘f IS bit-exact
        if a["fn"] is None and b["fn"] is None \
                and not _is_identity(a) and not _is_identity(b):
            why = "f32 composition reorders rounding" \
                if a["op"] in _F32_OPS and b["op"] in _F32_OPS \
                else "the composed semantics are not registered"
            decisions.append(
                f"kept '{a['name']}'|'{b['name']}' separate: no bit-exact "
                f"fused kernel for {a['op']}∘{b['op']} in the op registry "
                f"({why})")
    return survivors + tail, fused_from, decisions


# ----------------------------------------------------------------- emission


def _to_stage(s: dict) -> Stage:
    if s["kind"] == "reduce":
        if s["fn"] is not None:
            # deep-copy the caller's init per build: builders are shared
            # and every reducer in this repo rebinds acc keys in place,
            # so a shared init would make a second run start from the
            # first run's totals (the registry path is factory-fresh
            # already)
            fn, init = s["fn"], copy.deepcopy(s["init"])
        else:
            from repro.dsl.reducers import resolve_reducer
            fn, init = resolve_reducer(s["reducer"])
        return Stage(s["name"], op="custom", reduce_fn=fn, reduce_init=init,
                     workers=int(s["workers"]), sgx=bool(s["sgx"]))
    if s["fn"] is not None:
        return Stage(s["name"], op="custom", fn=s["fn"],
                     workers=int(s["workers"]), sgx=bool(s["sgx"]))
    return Stage(s["name"], op=s["op"], const=float(s["const"]),
                 workers=int(s["workers"]), sgx=bool(s["sgx"]))


def compile_pipeline(ops: Sequence[Op], *, mode: str = "enclave",
                     seed: int = 0, directory=None, window_chunks: int = 8,
                     fuse: bool = True,
                     rekey_every_n: Optional[int] = None,
                     tracer=None, monitor=None,
                     retry=None, chaos=None) -> Pipeline:
    """Validate, fuse, and emit a :class:`Pipeline` from a DSL op chain.

    ``rekey_every_n`` (when known at build time, e.g. from a spec file)
    triggers the eager cadence-vs-``epoch_history`` rejection the engine
    would otherwise raise at ``run()``.  ``tracer`` (from
    ``StreamBuilder.trace``) and ``monitor`` (from
    ``StreamBuilder.monitor``) are attached to the emitted pipeline;
    None keeps each at its zero-cost disabled default.  ``retry`` (from
    ``StreamBuilder.retry``) and ``chaos`` (from ``StreamBuilder.chaos``)
    enable the fault-tolerant engine the same way.
    """
    stage_dicts = validate(ops, mode)
    fused, fused_from, decisions = plan_fusion(stage_dicts, fuse)
    kw: Dict[str, Any] = {}
    if directory is not None:
        kw["directory"] = directory
    if tracer is not None:
        kw["tracer"] = tracer
    if monitor is not None:
        kw["monitor"] = monitor
    if retry is not None:
        kw["retry"] = retry
    if chaos is not None:
        kw["chaos"] = chaos
    p = Pipeline([_to_stage(s) for s in fused],
                 SecureStreamConfig(mode=mode),
                 seed=seed, window_chunks=window_chunks,
                 fusion={"fused_from": fused_from, "decisions": decisions},
                 **kw)
    if rekey_every_n and mode != "plain":
        # the same guard Pipeline.run applies — surfaced at build time
        p._clamp_window_for_rekey(p.window_chunks, int(rekey_every_n))
    return p
