"""Named terminal reducers, so declarative specs can reference them.

A TOML spec cannot carry a Python closure, but the paper's Listing-1 jobs
end in well-known reductions — so reducers register under a name and a
spec says ``reduce = "carrier_delay_stats"``.  Each registration is a
*factory* returning a fresh ``(fn, init)`` pair per pipeline build (a
shared mutable ``init`` across builds would make reruns accumulate).

Built-ins:

* ``carrier_delay_stats`` — the paper's own DelayedFlights benchmark
  (§5.2): per-carrier delayed-flight counts + delay sums over packed
  uint32 records (word 0 = carrier, word 1 = delay minutes).
* ``sum`` — elementwise running sum of chunks (the 8-stage acceptance
  pipeline's terminal fold).
* ``count`` — number of chunks that reached the sink.

Register your own::

    from repro.dsl import register_reducer

    @register_reducer("my_stats")
    def _my_stats(**kw):
        def fn(acc, chunk): ...
        return fn, init
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import numpy as np

from repro.data.synthetic import CARRIER_WORD, DELAY_WORD

ReducerFactory = Callable[..., Tuple[Callable, Any]]

REDUCERS: Dict[str, ReducerFactory] = {}


def register_reducer(name: str) -> Callable[[ReducerFactory],
                                            ReducerFactory]:
    """Decorator: register a ``(**kw) -> (fn, init)`` reducer factory
    under ``name`` for use in TOML specs and ``.reduce("name")``."""
    def deco(factory: ReducerFactory) -> ReducerFactory:
        REDUCERS[name] = factory
        return factory
    return deco


def resolve_reducer(name: str, **kw) -> Tuple[Callable, Any]:
    """Instantiate a registered reducer -> fresh ``(fn, init)``."""
    factory = REDUCERS.get(name)
    if factory is None:
        raise KeyError(f"unknown reducer {name!r}; registered: "
                       f"{sorted(REDUCERS)} "
                       f"(add one with @register_reducer)")
    return factory(**kw)


@register_reducer("carrier_delay_stats")
def _carrier_delay_stats(num_carriers: int = 20):
    """Per-carrier delayed count + delay-minute sum (paper §5.2)."""
    def fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(
            carrier[valid], minlength=num_carriers)
        acc["sum"] = acc["sum"] + np.bincount(
            carrier[valid], weights=delay[valid], minlength=num_carriers)
        return acc
    return fn, {"count": np.zeros(num_carriers),
                "sum": np.zeros(num_carriers)}


@register_reducer("sum")
def _sum():
    """Elementwise running sum over chunks (None-seeded first fold)."""
    def fn(acc, chunk):
        return chunk if acc is None else acc + np.asarray(chunk)
    return fn, None


@register_reducer("count")
def _count():
    """Count of chunks that survived to the sink."""
    return (lambda acc, chunk: acc + 1), 0
