"""Declarative pipeline specs: the paper's Listing 1, as TOML (or a dict).

The paper declares a pipeline as named stages with worker counts and an
SGX placement constraint.  The same shape here — 12 lines for the whole
DelayedFlights job::

    mode = "enclave"
    [stage.sgx_mapper]
    op = "identity"
    workers = 2
    constraint = "sgx"
    [stage.sgx_filter]
    op = "delay_filter_u32"
    const = 15
    workers = 2
    constraint = "sgx"
    [stage.reducer]
    reduce = "carrier_delay_stats"

``load_spec`` parses this (file path, TOML text, or an already-parsed
dict) into the same :class:`repro.dsl.builder.StreamBuilder` the fluent
API produces, so both forms compile through one validator/fusion path
and are bit-identical to each other.

Accepted keys — top level (or under ``[pipeline]``): ``mode``,
``rekey_every_n``, ``window_chunks``, ``seed``, ``name``.  Per stage
(``[stage.<name>]`` tables in file order, or a ``[[stage]]`` array with
explicit ``name`` keys): ``op``/``const`` (static registry operator),
``reduce`` (a registered reducer name), ``workers`` (alias ``count``,
the paper's key), and ``constraint`` — ``"sgx"`` or the paper's literal
``"type==sgx"`` mean enclave placement; anything else (or absent) means
unconstrained.

Python 3.10 has no ``tomllib``; a minimal built-in parser covers the
subset above (sections, array-of-table headers, scalar ``key = value``)
and ``tomllib`` is used when available.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Union

from repro.dsl.builder import StreamBuilder, stream

# the paper writes `constraint:type==sgx`; accept the obvious spellings
_SGX_WORDS = ("sgx", "type==sgx", "type == sgx")

FILTER_OPS = ("delay_filter_u32", "threshold_mask")

# eager-validation contract: a typo'd key must fail the load, not run
# the pipeline with a silent default (`conts = 15` -> threshold 0)
_TOP_KEYS = ("mode", "rekey_every_n", "window_chunks", "seed", "name",
             "pipeline", "stage")
_STAGE_KEYS = ("name", "op", "const", "workers", "count", "constraint",
               "kind", "reduce")


class SpecError(ValueError):
    """A malformed spec document (parse- or shape-level)."""


# --------------------------------------------------------------- parsing


def _parse_scalar(v: str, where: str) -> Any:
    v = v.strip()
    if len(v) >= 2 and v[0] == v[-1] and v[0] in "\"'":
        return v[1:-1]
    if v in ("true", "false"):
        return v == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        raise SpecError(f"{where}: cannot parse value {v!r} "
                        f"(expected string/int/float/bool)") from None


def _strip_comment(line: str) -> str:
    out, quote = [], None
    for ch in line:
        if quote:
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
        elif ch == "#":
            break
        out.append(ch)
    return "".join(out)


def _parse_mini_toml(text: str) -> Dict[str, Any]:
    """Minimal TOML subset parser (py<3.11 fallback): ``[a.b]`` tables,
    ``[[a]]`` arrays of tables, scalar ``key = value`` pairs.  Table
    order is preserved (dict insertion order) — stage order is
    significant."""
    root: Dict[str, Any] = {}
    cur = root
    for ln, raw in enumerate(text.splitlines(), 1):
        line = _strip_comment(raw).strip()
        if not line:
            continue
        where = f"line {ln}"
        if line.startswith("[[") and line.endswith("]]"):
            path = line[2:-2].strip().split(".")
            parent = root
            for p in path[:-1]:
                parent = parent.setdefault(p, {})
            arr = parent.setdefault(path[-1], [])
            if not isinstance(arr, list):
                raise SpecError(f"{where}: {'.'.join(path)!r} is both a "
                                f"table and an array of tables")
            cur = {}
            arr.append(cur)
        elif line.startswith("[") and line.endswith("]"):
            path = line[1:-1].strip().split(".")
            parent = root
            for p in path[:-1]:
                parent = parent.setdefault(p, {})
            cur = parent.setdefault(path[-1], {})
            if not isinstance(cur, dict):
                raise SpecError(f"{where}: {'.'.join(path)!r} redefined "
                                f"as a table")
        elif "=" in line:
            k, v = line.split("=", 1)
            cur[k.strip()] = _parse_scalar(v, where)
        else:
            raise SpecError(f"{where}: cannot parse {raw.strip()!r}")
    return root


def parse_toml(text: str) -> Dict[str, Any]:
    """Parse TOML text — stdlib ``tomllib`` when present (3.11+), the
    built-in subset parser otherwise."""
    try:
        import tomllib
    except ModuleNotFoundError:
        return _parse_mini_toml(text)
    return tomllib.loads(text)


# --------------------------------------------------------------- loading


def _stage_list(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    stages = doc.get("stage")
    if stages is None:
        raise SpecError("spec has no stages: add [stage.<name>] tables "
                        "or a [[stage]] array")
    if isinstance(stages, dict):                 # [stage.<name>] form
        out = []
        for name, body in stages.items():
            if not isinstance(body, dict):
                raise SpecError(f"[stage.{name}] must be a table")
            out.append({"name": name, **body})
        return out
    if isinstance(stages, list):                 # [[stage]] form
        for i, s in enumerate(stages):
            if "name" not in s:
                raise SpecError(f"[[stage]] #{i} is missing a name")
        return [dict(s) for s in stages]
    raise SpecError(f"unrecognized stage collection: {type(stages)}")


def _is_sgx(constraint: Any) -> bool:
    return isinstance(constraint, str) \
        and constraint.strip().lower() in _SGX_WORDS


def load_spec(spec: Union[str, "os.PathLike", Dict[str, Any]],
              source=None, *,
              reducers: Optional[Dict[str, Any]] = None) -> StreamBuilder:
    """Spec -> :class:`StreamBuilder` (same builder the fluent API uses).

    ``spec``: a dict, a path to a ``.toml`` file, or TOML text.
    ``source``: optional chunk iterable bound now (else pass it to
    ``.run``).  ``reducers``: extra ``{name: (fn, init)}`` pairs visible
    to this spec only, on top of the global registry.
    """
    if isinstance(spec, dict):
        doc = dict(spec)
    else:
        text = str(spec)
        if "\n" not in text and (os.path.exists(text)
                                 or text.endswith(".toml")):
            with open(text, "r") as f:
                text = f.read()
        doc = parse_toml(text)

    for k in doc:
        if k not in _TOP_KEYS:
            raise SpecError(f"unknown top-level key {k!r}; accepted: "
                            f"{sorted(_TOP_KEYS)}")
    pl = doc.get("pipeline", {})
    for k in pl:
        if k not in _TOP_KEYS or k in ("pipeline", "stage"):
            raise SpecError(f"unknown [pipeline] key {k!r}; accepted: "
                            f"{sorted(set(_TOP_KEYS) - {'pipeline', 'stage'})}")
    top = dict(pl)
    for k in ("mode", "rekey_every_n", "window_chunks", "seed", "name"):
        if k in doc and k not in top:
            top[k] = doc[k]

    sb = stream(source)
    if "mode" in top:
        sb = sb.secure(top["mode"])
    if "window_chunks" in top:
        sb = sb.window(int(top["window_chunks"]))
    if "seed" in top:
        sb = sb.seed(int(top["seed"]))
    if "rekey_every_n" in top:
        sb = sb._with_settings(rekey_every_n=int(top["rekey_every_n"]))

    for s in _stage_list(doc):
        name = s["name"]
        for k in s:
            if k not in _STAGE_KEYS:
                raise SpecError(
                    f"stage {name!r}: unknown key {k!r}; accepted: "
                    f"{sorted(_STAGE_KEYS)}")
        workers = int(s.get("workers", s.get("count", 1)))
        sgx = _is_sgx(s.get("constraint"))
        if "reduce" in s:
            rname = s["reduce"]
            if reducers and rname in reducers:
                fn, init = reducers[rname]
                sb = sb.reduce(fn, init, name=name)
            else:
                sb = sb.reduce(rname, name=name)   # global registry
            continue
        if "op" not in s:
            raise SpecError(f"stage {name!r} needs an 'op' (static "
                            f"operator) or a 'reduce' (named reducer)")
        op, const = s["op"], float(s.get("const", 0.0))
        if s.get("kind", "filter" if op in FILTER_OPS else "map") \
                == "filter":
            sb = sb.filter(op, const=const, name=name, workers=workers,
                           sgx=sgx)
        else:
            sb = sb.map(op, const=const, name=name, workers=workers,
                        sgx=sgx)
    return sb
