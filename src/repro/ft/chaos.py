"""Deterministic fault injection for the window engine.

A ``ChaosPlan`` is a seed-scheduled list of ``FaultSpec``s with hook
points at every engine boundary the pipeline exposes:

* ``crash``        — a worker is lost before (or after) executing its
                     share of a window; ``fatal`` crashes remove the
                     worker for the rest of the run, transient ones
                     make exactly one dispatch disappear.
* ``stall``        — a worker's share takes ``seconds`` longer than it
                     should; the straggler detector + backup dispatcher
                     decide whether a speculative backup wins.
* ``tamper``       — the ciphertext of a share is flipped in flight
                     (MAC failure downstream; the replay buffer must
                     re-execute from the retained clean rows).
* ``drop_verdict`` — the host-side MAC verdict sync for a share is
                     lost; the engine must treat the share as
                     unverified and replay it.
* ``enroll_fail``  — a live enrollment (spare admission) fails its
                     attestation handshake; injected through
                     ``KeyDirectory.admission_interceptor`` so the
                     rejection takes the REAL quote_rejected audit
                     path.

The plan is consulted by ``core.pipeline`` at each hop, and every poll
consumes at most one matching un-fired spec — so a given (seed, plan)
replays bit-for-bit: same faults, same rounds, same workers, every run.
``replay()`` resets the fired flags for a second identical pass.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

KINDS = ("crash", "stall", "tamper", "drop_verdict", "enroll_fail")


@dataclass
class FaultSpec:
    """One scheduled fault.  ``stage``/``round``/``worker`` address the
    hook point; fields beyond that parameterize the fault kind."""
    kind: str
    stage: str = ""
    round: int = 0
    worker: int = 0
    when: str = "before"      # crash: "before" (share lost) / "after"
                              # (share computed, result lost)
    fatal: bool = False       # crash: worker never comes back
    rows: int = 1             # tamper: number of leading rows to corrupt
    seconds: float = 0.0      # stall: artificial extra latency observed
    fired: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class ChaosPlan:
    """A replayable fault schedule.  ``events`` records each fault as it
    fires — (kind, stage, round, worker) — in firing order, so a test
    can assert exactly-once audit coverage against it."""
    faults: List[FaultSpec] = field(default_factory=list)
    seed: Optional[int] = None
    events: List[Tuple[str, str, int, int]] = field(default_factory=list)

    # ---- construction ----------------------------------------------------
    @classmethod
    def seeded(cls, seed: int, stage_workers: Sequence[Tuple[str, int]], *,
               rounds: int = 3, n_faults: int = 4,
               kinds: Sequence[str] = ("crash", "stall", "tamper",
                                       "drop_verdict")) -> "ChaosPlan":
        """Deterministically generate ``n_faults`` faults over the given
        ``(stage_name, n_workers)`` topology.  Same seed -> same plan.
        Fault addresses (stage, round, worker) are kept DISTINCT so each
        injected fault has an unambiguous exactly-once audit footprint
        (two faults on one share would entangle their recovery paths)."""
        rng = random.Random(f"repro-chaos-{seed}")
        faults = []
        used = set()
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            for _try in range(64):
                stage, nw = rng.choice(list(stage_workers))
                addr = (stage, rng.randrange(rounds),
                        rng.randrange(max(nw, 1)))
                if addr not in used:
                    break
            else:
                continue                   # topology saturated: skip
            used.add(addr)
            spec = FaultSpec(
                kind=kind, stage=addr[0], round=addr[1], worker=addr[2],
                when=rng.choice(("before", "after")) if kind == "crash"
                else "before",
                fatal=(kind == "crash" and rng.random() < 0.25),
                rows=rng.randrange(1, 3),
                seconds=rng.uniform(0.5, 2.0) if kind == "stall" else 0.0,
            )
            faults.append(spec)
        return cls(faults=faults, seed=seed)

    # ---- engine hook points ----------------------------------------------
    def _take(self, kind: str, stage: str, rnd: int,
              worker: int) -> Optional[FaultSpec]:
        for f in self.faults:
            if (not f.fired and f.kind == kind and f.stage == stage
                    and f.round == rnd and f.worker == worker):
                f.fired = True
                self.events.append((kind, stage, rnd, worker))
                return f
        return None

    def crash_for(self, stage: str, rnd: int, worker: int):
        return self._take("crash", stage, rnd, worker)

    def stall_for(self, stage: str, rnd: int, worker: int):
        return self._take("stall", stage, rnd, worker)

    def tamper_for(self, stage: str, rnd: int, worker: int):
        return self._take("tamper", stage, rnd, worker)

    def drop_verdict_for(self, stage: str, rnd: int, worker: int):
        return self._take("drop_verdict", stage, rnd, worker)

    def enroll_failure(self, worker_id: str) -> Optional[str]:
        """Admission-interceptor hook: a pending ``enroll_fail`` spec
        rejects the next live enrollment, whoever it names."""
        for f in self.faults:
            if not f.fired and f.kind == "enroll_fail":
                f.fired = True
                self.events.append(("enroll_fail", worker_id, -1, -1))
                return "chaos-injected enrollment failure"
        return None

    # ---- fault application -----------------------------------------------
    @staticmethod
    def apply_tamper(spec: FaultSpec, win):
        """Return a tampered COPY of ``win`` (the caller's retained clean
        rows must stay clean for the replay path): flip word 0 of the
        first ``spec.rows`` rows."""
        import jax.numpy as jnp
        k = min(max(spec.rows, 1), win.words.shape[0])
        flip = jnp.uint32(0xDEADBEEF)
        words = win.words.at[:k, 0].set(win.words[:k, 0] ^ flip)
        return replace(win, words=words)

    # ---- replay ----------------------------------------------------------
    def replay(self) -> "ChaosPlan":
        """Reset fired flags + event log so the SAME schedule re-fires
        identically on a second run (bit-for-bit replayability)."""
        for f in self.faults:
            f.fired = False
        self.events.clear()
        return self

    def pending(self) -> List[FaultSpec]:
        return [f for f in self.faults if not f.fired]
