"""Failure handling: detection/simulation + checkpoint-restart recovery.

On a real fleet, node failures surface as collective timeouts or device
errors; the recovery primitive is identical either way: restore the last
sealed checkpoint and continue (possibly on a *different* mesh — elastic
restore, repro.ckpt).  This module provides the policy layer:

* ``FailureInjector`` — deterministic fault schedule for tests/examples
  (step -> kind), standing in for real device loss on CPU;
* ``run_with_recovery`` — the supervisor loop: run the step function,
  on failure restore from checkpoint and replay the data stream to the
  restored step (streams are counter-addressed, so replay = fast-forward
  of the chunk counter — the SecureStreams nonce discipline gives
  exactly-once semantics for free).

Revocation (repro.attest) is handled like a failed node: when a failure
names a worker (``worker_id`` on the exception, or an injector kind of
``"revoked:<id>"``), the supervisor quarantines it in the KeyDirectory —
its quotes stop verifying, its sessions are torn down — then runs the
``reestablish`` hook (re-handshake on the surviving set) before the
checkpoint restore.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


class SimulatedFailure(RuntimeError):
    def __init__(self, kind: str, step: int):
        super().__init__(f"simulated {kind} at step {step}")
        self.kind = kind
        self.step = step
        # "revoked:<worker_id>" marks a compromised-worker eviction; the
        # supervisor treats it as a failed node + revocation.
        self.worker_id = kind.split(":", 1)[1] \
            if kind.startswith("revoked:") else None


@dataclass
class FailureInjector:
    schedule: Dict[int, str] = field(default_factory=dict)  # step -> kind
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        kind = self.schedule.get(step)
        if kind and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(kind, step)


@dataclass
class RecoveryReport:
    restarts: int = 0
    failures: List[Tuple[int, str]] = field(default_factory=list)
    replayed_steps: int = 0
    final_step: int = -1
    revoked_workers: List[str] = field(default_factory=list)


def run_with_recovery(
    *,
    total_steps: int,
    run_steps: Callable[[int, int], int],
    # run_steps(start_step, end_step) -> last completed step; raises on fail
    restore: Callable[[], int],
    # restore() -> step to resume from (restores model state internally)
    max_restarts: int = 8,
    directory=None,
    # repro.attest KeyDirectory: failures that name a worker_id revoke it
    reestablish: Optional[Callable[[Any], None]] = None,
    # reestablish(directory): re-handshake sessions on the surviving set
) -> RecoveryReport:
    """Supervisor loop: keep running until total_steps or restart budget.

    A failure carrying a ``worker_id`` (e.g. an injector kind of
    ``"revoked:<id>"`` or repro.attest's RevokedWorkerError) is a
    compromised worker, not just a crashed one: it is revoked in
    ``directory`` (quarantined + its sessions dropped) and
    ``reestablish`` runs before the restore so the survivors re-handshake
    — then recovery proceeds exactly like a node loss.
    """
    report = RecoveryReport()
    step = restore()
    while step < total_steps:
        resumed_from = step          # last state-consistent step
        try:
            step = run_steps(step, total_steps)
        except Exception as e:  # noqa: BLE001 — any failure -> recover
            report.restarts += 1
            # honest failure accounting: trust the exception's own step
            # when it carries one; otherwise the best known lower bound
            # is the step this attempt RESUMED from, not the loop
            # variable (which may alias a later partial advance)
            failed_at = getattr(e, "step", None)
            if failed_at is None:
                failed_at = resumed_from
            report.failures.append((failed_at, repr(e)))
            if report.restarts > max_restarts:
                raise RuntimeError(
                    f"restart budget exhausted after {report.restarts}") from e
            wid = getattr(e, "worker_id", None)
            if wid is not None and directory is not None:
                from repro.attest.directory import KeyDirectoryError
                if wid not in directory.policy.revoked:
                    try:
                        directory.revoke(wid)
                    except KeyDirectoryError:
                        wid = None        # names no enrolled worker
                if wid is not None:
                    report.revoked_workers.append(wid)
                    if reestablish is not None:
                        reestablish(directory)
            resumed = restore()
            if resumed > failed_at:
                # a checkpoint from AFTER the failure step means restore
                # did not rewind to a state-consistent point (stale or
                # foreign checkpoint directory) — continuing would skip
                # data; replaying from it would double-fold.  Refuse.
                raise RuntimeError(
                    f"restore() resumed at step {resumed}, past the "
                    f"failure at step {failed_at} — the checkpoint does "
                    f"not precede the failure, recovery cannot replay "
                    f"exactly") from e
            report.replayed_steps += failed_at - resumed
            step = resumed
    report.final_step = step
    return report
