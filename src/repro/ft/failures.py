"""Failure handling: detection/simulation + checkpoint-restart recovery.

On a real fleet, node failures surface as collective timeouts or device
errors; the recovery primitive is identical either way: restore the last
sealed checkpoint and continue (possibly on a *different* mesh — elastic
restore, repro.ckpt).  This module provides the policy layer:

* ``FailureInjector`` — deterministic fault schedule for tests/examples
  (step -> kind), standing in for real device loss on CPU;
* ``run_with_recovery`` — the supervisor loop: run the step function,
  on failure restore from checkpoint and replay the data stream to the
  restored step (streams are counter-addressed, so replay = fast-forward
  of the chunk counter — the SecureStreams nonce discipline gives
  exactly-once semantics for free).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


class SimulatedFailure(RuntimeError):
    def __init__(self, kind: str, step: int):
        super().__init__(f"simulated {kind} at step {step}")
        self.kind = kind
        self.step = step


@dataclass
class FailureInjector:
    schedule: Dict[int, str] = field(default_factory=dict)  # step -> kind
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        kind = self.schedule.get(step)
        if kind and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(kind, step)


@dataclass
class RecoveryReport:
    restarts: int = 0
    failures: List[Tuple[int, str]] = field(default_factory=list)
    replayed_steps: int = 0
    final_step: int = -1


def run_with_recovery(
    *,
    total_steps: int,
    run_steps: Callable[[int, int], int],
    # run_steps(start_step, end_step) -> last completed step; raises on fail
    restore: Callable[[], int],
    # restore() -> step to resume from (restores model state internally)
    max_restarts: int = 8,
) -> RecoveryReport:
    """Supervisor loop: keep running until total_steps or restart budget."""
    report = RecoveryReport()
    step = restore()
    while step < total_steps:
        try:
            step = run_steps(step, total_steps)
        except Exception as e:  # noqa: BLE001 — any failure -> recover
            report.restarts += 1
            failed_at = getattr(e, "step", step)
            report.failures.append((failed_at, repr(e)))
            if report.restarts > max_restarts:
                raise RuntimeError(
                    f"restart budget exhausted after {report.restarts}") from e
            resumed = restore()
            report.replayed_steps += max(failed_at - resumed, 0)
            step = resumed
    report.final_step = step
    return report
