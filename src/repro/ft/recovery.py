"""Replay-based failover: the sealed ingress replay buffer + the run
context that ties the fault-tolerance pieces together.

The replay buffer is the recovery invariant's anchor: every window's
sealed input parts are RETAINED (still under their directory-reserved
nonce blocks) until the window's single host-side verdict sync has been
folded into the output — only then does ``ack`` release them and the
watermark advance.  Any share whose result is lost (worker crash, stall
loss to a backup, tamper, dropped verdict) is re-executed from these
retained rows, re-sealed under FRESH counter blocks reserved from the
ingress edge, so recovery never reuses a (key, nonce, counter) triple
and the terminal reduce stays bit-identical to the fault-free run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.ft.chaos import ChaosPlan
from repro.ft.retry import RetryPolicy
from repro.ft.straggler import BackupDispatcher, StragglerDetector
from repro.obs.metrics import REGISTRY


class ReplayBuffer:
    """Sealed ingress rows retained per (stage, round) until acked.

    Windows are retained per stage hop: the rows a stage consumed are
    exactly what a re-execution of that stage's share needs (already
    sealed under the stage's inbound edge key).  ``watermark`` is the
    highest round fully acked at every retaining stage — rows at or
    below it have been garbage-collected.
    """

    def __init__(self):
        self._held: Dict[Tuple[str, int], List] = {}
        self._acked_rounds: Dict[str, int] = {}
        self._gauge = REGISTRY.gauge("ft.replay.retained_rows")

    def retain(self, stage: str, rnd: int, parts: List) -> None:
        self._held[(stage, rnd)] = parts
        self._gauge.set(self.retained_rows())

    def get(self, stage: str, rnd: int) -> Optional[List]:
        return self._held.get((stage, rnd))

    def ack(self, stage: str, rnd: int) -> None:
        """The verdict sync for (stage, round) is folded in: release."""
        self._held.pop((stage, rnd), None)
        prev = self._acked_rounds.get(stage, -1)
        self._acked_rounds[stage] = max(prev, rnd)
        self._gauge.set(self.retained_rows())

    def watermark(self) -> int:
        """Highest round acked by every stage seen so far (GC frontier)."""
        if not self._acked_rounds:
            return -1
        return min(self._acked_rounds.values())

    def retained_rows(self) -> int:
        return sum(sum(len(p) for p in parts)
                   for parts in self._held.values())


@dataclass
class FTContext:
    """Per-run fault-tolerance state, created by the pipeline when retry
    or chaos is enabled.  Holds the policy, the (optional) fault plan,
    the replay buffer, per-stage straggler detectors + backup
    dispatchers, the set of workers declared dead, and the ft.* counters
    the monitor exposes."""
    policy: RetryPolicy = field(default_factory=RetryPolicy)
    chaos: Optional[ChaosPlan] = None
    buffer: ReplayBuffer = field(default_factory=ReplayBuffer)
    detectors: Dict[str, StragglerDetector] = field(default_factory=dict)
    dispatchers: Dict[str, BackupDispatcher] = field(default_factory=dict)
    dead: Set[Tuple[str, int]] = field(default_factory=set)
    _share_seq: int = 0

    def __post_init__(self):
        self.retries = REGISTRY.counter("ft.retries")
        self.failovers = REGISTRY.counter("ft.failovers")
        self.backups = REGISTRY.counter("ft.backups")
        self.replays = REGISTRY.counter("ft.replays")
        self.worker_failures = REGISTRY.counter("ft.worker_failures")
        self.enroll_failures = REGISTRY.counter("ft.enroll_failures")

    def detector(self, stage: str) -> StragglerDetector:
        if stage not in self.detectors:
            self.detectors[stage] = StragglerDetector()
        return self.detectors[stage]

    def dispatcher(self, stage: str, num_workers: int) -> BackupDispatcher:
        d = self.dispatchers.get(stage)
        if d is None:
            d = BackupDispatcher(num_workers=num_workers)
            self.dispatchers[stage] = d
        else:
            d.num_workers = max(d.num_workers, num_workers)
        return d

    def next_share_id(self) -> int:
        sid = self._share_seq
        self._share_seq += 1
        return sid

    def mark_dead(self, stage: str, worker: int) -> None:
        self.dead.add((stage, worker))

    def is_dead(self, stage: str, worker: int) -> bool:
        return (stage, worker) in self.dead
