"""Per-share retry policy: bounded attempts, exponential backoff, and a
straggler-fed share timeout.

The unit of retry is one worker's batched open -> op -> seal share of a
window (the engine's unit of device work).  A retried share must NEVER
re-seal under a (key, nonce, counter) triple that was already spent on
the outbound key — the engine reserves a FRESH counter block from the
ingress edge for every re-execution, so the policy here is purely about
scheduling: how many attempts, how long to wait between them, and when
a slow share should lose to a speculative backup.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.ft.straggler import StragglerDetector


@dataclass
class RetryPolicy:
    """Scheduling knobs for per-share retry / failover / backup.

    ``share_timeout_s`` pins the stall cutoff; when None, the cutoff is
    fed by the per-stage ``StragglerDetector`` (``timeout_scale`` x the
    observed mean share time once the detector is warmed up).
    """
    max_attempts: int = 3          # total tries on the SAME worker
    backoff_base_s: float = 0.0    # first retry delay (0 = immediate: the
                                   # schedule is deterministic either way)
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0
    deadline_s: Optional[float] = None   # wall-clock budget per share
    share_timeout_s: Optional[float] = None
    timeout_scale: float = 4.0
    min_timeout_s: float = 0.05
    replay_mac_failures: bool = True     # tampered rows re-run from the
                                         # replay buffer instead of dropping
    failover: bool = True                # move a dead share to a survivor
    enroll_spare: bool = True            # no survivors -> enroll a spare
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)

    def backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based)."""
        if self.backoff_base_s <= 0.0:
            return 0.0
        d = self.backoff_base_s * (self.backoff_factor ** (attempt - 1))
        return min(d, self.max_backoff_s)

    def timeout_for(self, detector: Optional[StragglerDetector]) -> float:
        """Stall cutoff for one share, in seconds."""
        if self.share_timeout_s is not None:
            return self.share_timeout_s
        if detector is not None and detector.n >= detector.warmup:
            return max(self.min_timeout_s,
                       self.timeout_scale * detector.mean)
        return self.min_timeout_s
