"""Straggler mitigation.

Two mechanisms, mirroring production practice:

* **Detection**: per-step wall-time EWMA + robust z-score; a worker (or
  the whole step, in SPMD) flagging persistently above ``threshold`` sigma
  is a straggler.  On TPU pods the SPMD step time is the max over chips,
  so detection at the step level catches any slow chip.
* **Backup dispatch** (input stages): the SecureStreams router re-issues
  the straggler's pending chunk to the least-loaded peer worker; because
  chunks are counter-addressed and idempotent (AEAD nonce = counter),
  duplicated completions deduplicate naturally — the reactive-router
  version of MapReduce speculative execution.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class StragglerDetector:
    alpha: float = 0.1           # EWMA smoothing
    threshold: float = 3.0       # robust z threshold
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0

    def observe(self, seconds: float) -> bool:
        """Feed one step time; True if this step is a straggler outlier."""
        self.n += 1
        if self.n <= self.warmup:
            # prime the statistics
            d = seconds - self.mean
            self.mean += d / self.n
            self.var += d * (seconds - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.n - 1, 1), 1e-12))
        z = (seconds - self.mean) / max(std, 1e-9)
        # robust: need BOTH a z-outlier and a material relative slowdown
        is_straggler = z > self.threshold and seconds > 1.5 * self.mean
        if not is_straggler:
            # only fold non-outliers into the baseline
            self.mean = (1 - self.alpha) * self.mean + self.alpha * seconds
            self.var = (1 - self.alpha) * self.var + self.alpha * (
                seconds - self.mean) ** 2
        return is_straggler


@dataclass
class BackupDispatcher:
    """Speculative re-execution for input-stage chunks."""
    num_workers: int
    inflight: Dict[int, int] = field(default_factory=dict)   # chunk -> worker
    completed: set = field(default_factory=set)
    duplicates: int = 0
    backups: int = 0

    def assign(self, chunk_id: int) -> int:
        w = chunk_id % self.num_workers
        self.inflight[chunk_id] = w
        return w

    def track(self, chunk_id: int, worker: int) -> int:
        """Record an externally-chosen assignment (the window engine does
        its own round-robin; the dispatcher still needs the mapping so
        ``reissue`` picks a DIFFERENT worker for the backup copy)."""
        self.inflight[chunk_id] = worker
        return worker

    def reissue(self, chunk_id: int) -> Optional[int]:
        """Straggling chunk: send a backup copy to the next worker."""
        if chunk_id in self.completed:
            return None
        w = (self.inflight.get(chunk_id, chunk_id) + 1) % self.num_workers
        self.backups += 1
        return w

    def complete(self, chunk_id: int) -> bool:
        """Returns True the first time a chunk completes (dedup)."""
        if chunk_id in self.completed:
            self.duplicates += 1
            return False
        self.completed.add(chunk_id)
        self.inflight.pop(chunk_id, None)
        return True
