"""Pallas TPU kernel: ChaCha20-CTR encrypt/decrypt over uint32 word blocks.

Grid: one program per tile of `block_rows` cipher blocks; each block is 16
uint32 words, so a tile is a (block_rows, 16) u32 VMEM buffer (block_rows=512
=> 32 KiB in + 32 KiB out, comfortably inside VMEM with double buffering).
The keystream is derived in-register from (key, nonce, counter) — the
HBM->VMEM DMA moves only ciphertext, which is the paper's MEE boundary
analogy (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.chacha20.common import keystream_vectors

U32 = jnp.uint32


def _chacha_kernel(key_ref, nonce_ref, ctr_ref, data_ref, out_ref, *,
                   block_rows: int):
    pid = pl.program_id(0)
    key = [key_ref[0, i] for i in range(8)]
    nonce = [nonce_ref[0, i] for i in range(3)]
    base = ctr_ref[0, 0] + (pid * block_rows).astype(U32)
    counters = base + jax.lax.broadcasted_iota(U32, (block_rows,), 0)
    ks = keystream_vectors(key, nonce, counters)      # 16 x (rows,)
    data = data_ref[...]                              # (rows, 16) u32
    ks_mat = jnp.stack(ks, axis=-1)                   # (rows, 16)
    out_ref[...] = data ^ ks_mat


def _chacha_rows_kernel(key_ref, nonce_ref, ctr_ref, data_ref, out_ref):
    """Per-row (key, nonce, counter) tile: the batched-AEAD fast path.

    Every VMEM row is one cipher block with its own key/nonce/counter
    column vectors, so a whole (batch, counters 0..N) seal batch is a
    single grid sweep — no per-item dispatch.
    """
    key = [key_ref[:, i] for i in range(8)]       # 8 x (rows,)
    nonce = [nonce_ref[:, i] for i in range(3)]   # 3 x (rows,)
    counters = ctr_ref[...]                       # (rows,)
    ks = keystream_vectors(key, nonce, counters)  # 16 x (rows,)
    out_ref[...] = data_ref[...] ^ jnp.stack(ks, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def chacha20_xor_rows(keys: jax.Array, nonces: jax.Array, counters: jax.Array,
                      data_rows: jax.Array, *, block_rows: int = 256,
                      interpret: bool = True) -> jax.Array:
    """XOR (R, 16) u32 rows with per-row keystream blocks.

    keys: (R, 8); nonces: (R, 3); counters: (R,).  R % block_rows == 0.
    """
    R = data_rows.shape[0]
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _chacha_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(data_rows.shape, U32),
        interpret=interpret,
    )(keys.astype(U32), nonces.astype(U32), counters.astype(U32), data_rows)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def chacha20_xor_blocks(key: jax.Array, nonce: jax.Array, counter0,
                        data_blocks: jax.Array, *, block_rows: int = 512,
                        interpret: bool = True) -> jax.Array:
    """data_blocks: (N, 16) u32, N % block_rows == 0. Returns XORed blocks."""
    N = data_blocks.shape[0]
    assert N % block_rows == 0, (N, block_rows)
    grid = (N // block_rows,)
    key2 = key.reshape(1, 8).astype(U32)
    nonce2 = nonce.reshape(1, 3).astype(U32)
    ctr = jnp.asarray(counter0, U32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_chacha_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(data_blocks.shape, U32),
        interpret=interpret,
    )(key2, nonce2, ctr, data_blocks)
