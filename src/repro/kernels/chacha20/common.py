"""ChaCha20 round function shared by the cipher and enclave-map kernels.

Written in plain jnp ops on uint32 vectors so the same code runs inside a
Pallas kernel body (VMEM tiles / vector registers on TPU) and in interpret
mode on CPU.  The state is kept as 16 separate (rows,) vectors — on TPU each
maps to (sublane, lane) tiles; the rounds are pure VPU element ops.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
CONSTANTS = (0x61707865, 0x3320646e, 0x79622d32, 0x6b206574)


def _rotl(x, n: int):
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def _qr(s: List, a: int, b: int, c: int, d: int) -> None:
    sa, sb, sc, sd = s[a], s[b], s[c], s[d]
    sa = sa + sb
    sd = _rotl(sd ^ sa, 16)
    sc = sc + sd
    sb = _rotl(sb ^ sc, 12)
    sa = sa + sb
    sd = _rotl(sd ^ sa, 8)
    sc = sc + sd
    sb = _rotl(sb ^ sc, 7)
    s[a], s[b], s[c], s[d] = sa, sb, sc, sd


def keystream_vectors(key_words, nonce_words, counters) -> List[jax.Array]:
    """16 keystream vectors, each shaped like `counters` ((rows,) u32).

    key_words: sequence of 8 u32 scalars; nonce_words: 3 u32 scalars.
    """
    shape = counters.shape
    init = []
    for c in CONSTANTS:
        init.append(jnp.full(shape, c, U32))
    for i in range(8):
        init.append(jnp.full(shape, 1, U32) * key_words[i])
    init.append(counters.astype(U32))
    for i in range(3):
        init.append(jnp.full(shape, 1, U32) * nonce_words[i])
    def double_round(_, s):
        s = list(s)
        _qr(s, 0, 4, 8, 12)
        _qr(s, 1, 5, 9, 13)
        _qr(s, 2, 6, 10, 14)
        _qr(s, 3, 7, 11, 15)
        _qr(s, 0, 5, 10, 15)
        _qr(s, 1, 6, 11, 12)
        _qr(s, 2, 7, 8, 13)
        _qr(s, 3, 4, 9, 14)
        return tuple(s)

    # rolled (not unrolled): the 10x smaller graph keeps per-shape compile
    # cost low enough for the AEAD fast path's shape-keyed cache
    s = jax.lax.fori_loop(0, 10, double_round, tuple(init))
    return [a + b for a, b in zip(s, init)]
