"""Public op: ChaCha20-CTR over flat uint32 words (auto-padded to blocks).

Chooses the Pallas kernel (interpret on CPU, compiled on TPU) and handles
the flat-words <-> (N,16)-blocks framing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.chacha20.chacha20 import chacha20_xor_blocks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def encrypt_words(key, nonce, words, counter0: int = 1, *,
                  block_rows: int = 512):
    n = words.shape[0]
    n_blocks = max((n + 15) // 16, 1)
    pad_rows = (-n_blocks) % block_rows
    total = (n_blocks + pad_rows) * 16
    padded = jnp.pad(words, (0, total - n)).reshape(-1, 16)
    out = chacha20_xor_blocks(key, nonce, counter0, padded,
                              block_rows=block_rows,
                              interpret=not _on_tpu())
    return out.reshape(-1)[:n]


decrypt_words = encrypt_words
