"""Public op: ChaCha20-CTR over flat uint32 words (auto-padded to blocks).

Chooses the Pallas kernel (interpret on CPU, compiled on TPU) and handles
the flat-words <-> (N,16)-blocks framing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.chacha20.chacha20 import chacha20_xor_blocks, \
    chacha20_xor_rows


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def xor_rows(key, nonces, counters, rows, *, block_rows: int = 256):
    """Per-row keystream XOR over (R, 16) u32 rows (auto-padded to tiles).

    key: (8,) shared or (R, 8) per-row; nonces: (R, 3); counters: (R,).
    The padded tail rows use key/nonce/counter zeros and are sliced off.
    """
    R = rows.shape[0]
    keys = key.reshape(1, 8) * jnp.ones((R, 1), jnp.uint32) \
        if key.ndim == 1 else key
    pad = (-R) % block_rows
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
        nonces = jnp.pad(nonces, ((0, pad), (0, 0)))
        counters = jnp.pad(counters, (0, pad))
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    out = chacha20_xor_rows(keys, nonces, counters, rows,
                            block_rows=block_rows, interpret=not _on_tpu())
    return out[:R]


def encrypt_words(key, nonce, words, counter0: int = 1, *,
                  block_rows: int = 512):
    n = words.shape[0]
    n_blocks = max((n + 15) // 16, 1)
    pad_rows = (-n_blocks) % block_rows
    total = (n_blocks + pad_rows) * 16
    padded = jnp.pad(words, (0, total - n)).reshape(-1, 16)
    out = chacha20_xor_blocks(key, nonce, counter0, padded,
                              block_rows=block_rows,
                              interpret=not _on_tpu())
    return out.reshape(-1)[:n]


decrypt_words = encrypt_words
