"""Pure-jnp oracle for the ChaCha20 Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto import chacha20 as _c


def chacha20_xor_blocks_ref(key, nonce, counter0, data_blocks):
    N = data_blocks.shape[0]
    counters = jnp.asarray(counter0, jnp.uint32) + jnp.arange(N, dtype=jnp.uint32)
    ks = _c.chacha20_block(key, nonce, counters)   # (N, 16)
    return data_blocks ^ ks
