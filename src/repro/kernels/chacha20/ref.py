"""Pure-jnp oracle for the ChaCha20 Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto import chacha20 as _c


def chacha20_xor_blocks_ref(key, nonce, counter0, data_blocks):
    N = data_blocks.shape[0]
    counters = jnp.asarray(counter0, jnp.uint32) + jnp.arange(N, dtype=jnp.uint32)
    ks = _c.chacha20_block(key, nonce, counters)   # (N, 16)
    return data_blocks ^ ks


def chacha20_xor_rows_ref(keys, nonces, counters, data_rows):
    """Oracle for the per-row (key, nonce, counter) fast-path kernel."""
    keys = jnp.broadcast_to(keys.reshape(1, 8),
                            (data_rows.shape[0], 8)) \
        if keys.ndim == 1 else keys
    return data_rows ^ _c.chacha20_block_rows(keys, nonces, counters)
