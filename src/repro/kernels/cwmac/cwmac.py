"""Pallas TPU kernel: tiled Carter-Wegman MAC partials over GF(2^31-1).

The MAC tag is Σ_i limb_i · r^(n-i) + s.  Factoring by tile t of TS limbs:

    tag = Σ_t  r^(TS·(T-1-t)) · P_t,     P_t = Σ_j limb_{t,j} · r^(TS-j)

Each grid program computes one P_t from a VMEM tile using a precomputed
(TS,) powers vector (r^TS .. r^1); the per-tile scalar factors and the
final fold are O(T) scalar mulmods done in jnp (ops.py).  Integer-only
32-bit arithmetic throughout — see repro.crypto.cwmac for the field math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

U32 = jnp.uint32
P31 = np.uint32(0x7FFFFFFF)


def _fold31(x):
    x = (x & P31) + (x >> np.uint32(31))
    return jnp.where(x >= P31, x - P31, x)


def _addmod(a, b):
    return _fold31(a + b)


def _mulmod(a, b):
    a1 = a >> np.uint32(16)
    a0 = a & np.uint32(0xFFFF)
    b1 = b >> np.uint32(16)
    b0 = b & np.uint32(0xFFFF)
    mid = a0 * b1 + a1 * b0
    acc = _fold31(a0 * b0)
    acc = _addmod(acc, _fold31((a1 * b1) * np.uint32(2)))
    acc = _addmod(acc, _fold31(mid >> np.uint32(15)))
    acc = _addmod(acc, _fold31((mid & np.uint32(0x7FFF)) << np.uint32(16)))
    return acc


def _mac_tile_kernel(limbs_ref, pows_ref, out_ref, *, tile: int):
    terms = _mulmod(limbs_ref[...], pows_ref[...])   # (tile,) u32 < p
    # log-depth tree add-mod within the tile
    acc = terms
    n = tile
    while n > 1:
        half = n // 2
        acc = _addmod(acc[:half], acc[half:n])
        n = half
    out_ref[0] = acc[0]


def _mac_tile_batch_kernel(limbs_ref, pows_ref, out_ref, *, tile: int):
    terms = _mulmod(limbs_ref[0], pows_ref[0])   # (tile,) u32 < p
    acc = terms
    n = tile
    while n > 1:
        half = n // 2
        acc = _addmod(acc[:half], acc[half:n])
        n = half
    out_ref[0, 0] = acc[0]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def mac_partials_batch(limbs: jax.Array, powers: jax.Array, *,
                       tile: int = 4096, interpret: bool = True) -> jax.Array:
    """Per-row tiled partials: limbs (B, N) u32 < p with N % tile == 0;
    powers (B, tile) per-row [r_b^TS .. r_b^1].  Returns (B, N/tile)
    partials — one grid sweep covers every (row, tile) pair."""
    B, N = limbs.shape
    assert N % tile == 0 and (tile & (tile - 1)) == 0, (N, tile)
    grid = (B, N // tile)
    return pl.pallas_call(
        functools.partial(_mac_tile_batch_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile), lambda b, t: (b, t)),
            pl.BlockSpec((1, tile), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((B, N // tile), U32),
        interpret=interpret,
    )(limbs, powers)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def mac_partials(limbs: jax.Array, powers: jax.Array, *, tile: int = 4096,
                 interpret: bool = True) -> jax.Array:
    """limbs: (N,) u32 < p, N % tile == 0; powers: (tile,) = [r^TS..r^1].
    Returns (N/tile,) per-tile partials P_t."""
    N = limbs.shape[0]
    assert N % tile == 0 and (tile & (tile - 1)) == 0, (N, tile)
    grid = (N // tile,)
    return pl.pallas_call(
        functools.partial(_mac_tile_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N // tile,), U32),
        interpret=interpret,
    )(limbs, powers)
