"""Public op: full CW-MAC via the tiled Pallas kernel + jnp combine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.cwmac import _to_limbs, addmod, mulmod, r_powers
from repro.kernels.cwmac.cwmac import mac_partials

U32 = jnp.uint32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mac(words: jax.Array, r: jax.Array, s: jax.Array, *,
        tile: int = 4096) -> jax.Array:
    """tag = (sum_i limb_i r^(n-i) + s) mod 2^31-1, kernel-tiled."""
    limbs = _to_limbs(words)
    n = limbs.shape[0]
    pad = (-n) % tile
    # zero limbs contribute 0 regardless of power: pad at the FRONT so the
    # trailing (low-power) positions stay aligned with the message end.
    limbs = jnp.concatenate([jnp.zeros((pad,), U32), limbs])
    total = limbs.shape[0]
    T = total // tile
    pows_tile = r_powers(r, tile)                       # (tile,) = r^TS..r^1
    partials = mac_partials(limbs, pows_tile, tile=tile,
                            interpret=not _on_tpu())    # (T,)

    # tile t contributes P_t * r^(TS*(T-1-t)); compute scalar factors by
    # scanning with rTS = r^tile.
    rTS = pows_tile[0]                                  # r^tile

    def step(carry, p_t):
        # process tiles in order: acc = acc * rTS + P_t  (Horner over tiles)
        return addmod(mulmod(carry, rTS), p_t), None

    acc, _ = jax.lax.scan(step, jnp.zeros((), U32), partials)
    return addmod(acc, s)
