"""Public op: full CW-MAC via the tiled Pallas kernel + jnp combine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.cwmac import _to_limbs, addmod, mulmod, r_powers, \
    r_powers_batch, to_limbs_batch
from repro.kernels.cwmac.cwmac import mac_partials, mac_partials_batch

U32 = jnp.uint32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_tile(n_limbs: int, tile: int) -> int:
    """Largest power-of-two tile <= requested that doesn't over-pad tiny
    messages (padding is always to a whole number of tiles)."""
    t = 8
    while t < tile and t < n_limbs:
        t *= 2
    return t


def mac_batch(words: jax.Array, r: jax.Array, s: jax.Array, *,
              tile: int = 4096) -> jax.Array:
    """Row-wise kernel-tiled MAC: (B, N) words under (B,) keys -> (B,) tags.

    Same factoring as :func:`mac` but the partials kernel sweeps a
    (B, T) grid, so one launch MACs the whole batch."""
    limbs = to_limbs_batch(words)
    B, n = limbs.shape
    tile = _pick_tile(n, tile)
    pad = (-n) % tile
    # front-pad (zero limbs contribute 0) to keep low powers at message end
    limbs = jnp.concatenate([jnp.zeros((B, pad), U32), limbs], axis=1)
    T = limbs.shape[1] // tile
    pows_tile = r_powers_batch(r, tile)                  # (B, tile)
    partials = mac_partials_batch(limbs, pows_tile, tile=tile,
                                  interpret=not _on_tpu())  # (B, T)
    rTS = pows_tile[:, 0]                                # (B,) r^tile

    def step(carry, p_t):   # Horner over tiles, batched carry (B,)
        return addmod(mulmod(carry, rTS), p_t), None

    acc, _ = jax.lax.scan(step, jnp.zeros((B,), U32), partials.T)
    return addmod(acc, jnp.asarray(s, U32))


def mac2_batch(words: jax.Array, r1: jax.Array, s1: jax.Array,
               r2: jax.Array, s2: jax.Array, *,
               tile: int = 4096) -> jax.Array:
    """Row-wise dual-key MAC -> (B, 2) tags; both keys ride one launch."""
    B = words.shape[0]
    tags = mac_batch(jnp.concatenate([words, words]),
                     jnp.concatenate([jnp.asarray(r1, U32).reshape(-1),
                                      jnp.asarray(r2, U32).reshape(-1)]),
                     jnp.concatenate([jnp.asarray(s1, U32).reshape(-1),
                                      jnp.asarray(s2, U32).reshape(-1)]),
                     tile=tile)
    return jnp.stack([tags[:B], tags[B:]], axis=-1)


def mac(words: jax.Array, r: jax.Array, s: jax.Array, *,
        tile: int = 4096) -> jax.Array:
    """tag = (sum_i limb_i r^(n-i) + s) mod 2^31-1, kernel-tiled."""
    limbs = _to_limbs(words)
    n = limbs.shape[0]
    pad = (-n) % tile
    # zero limbs contribute 0 regardless of power: pad at the FRONT so the
    # trailing (low-power) positions stay aligned with the message end.
    limbs = jnp.concatenate([jnp.zeros((pad,), U32), limbs])
    total = limbs.shape[0]
    T = total // tile
    pows_tile = r_powers(r, tile)                       # (tile,) = r^TS..r^1
    partials = mac_partials(limbs, pows_tile, tile=tile,
                            interpret=not _on_tpu())    # (T,)

    # tile t contributes P_t * r^(TS*(T-1-t)); compute scalar factors by
    # scanning with rTS = r^tile.
    rTS = pows_tile[0]                                  # r^tile

    def step(carry, p_t):
        # process tiles in order: acc = acc * rTS + P_t  (Horner over tiles)
        return addmod(mulmod(carry, rTS), p_t), None

    acc, _ = jax.lax.scan(step, jnp.zeros((), U32), partials)
    return addmod(acc, s)
