"""Oracle for the CW-MAC kernel: repro.crypto.cwmac.mac (jnp) and the
python-int Horner reference."""
from repro.crypto.cwmac import mac as mac_ref, mac_reference  # noqa: F401
