"""Oracle for the CW-MAC kernel: repro.crypto.cwmac.mac (jnp) and the
python-int Horner reference; batched forms for the AEAD fast path."""
from repro.crypto.cwmac import mac as mac_ref, mac_reference  # noqa: F401
from repro.crypto.cwmac import mac_batch as mac_batch_ref  # noqa: F401
from repro.crypto.cwmac import mac2_batch as mac2_batch_ref  # noqa: F401
