"""Pallas TPU kernel: the ENCLAVE EXECUTOR — fused decrypt -> op -> encrypt.

This is the paper's central mechanism transposed to TPU (DESIGN.md §2):
the SGX enclave becomes a VMEM-resident kernel.  The HBM->VMEM DMA delivers
*ciphertext*; the keystream XOR (decrypt), the user operator, and the
re-encrypt all happen on VMEM tiles inside one kernel launch, so plaintext
never exists in HBM — exactly how the MEE keeps plaintext inside the CPU
package while DRAM sees ciphertext.

The operator is selected statically (the "enclaved bytecode" is fixed at
attestation time, like the paper's statically-linked Lua extensions):

* ``identity``       — pure re-key (router-to-router transfer)
* ``scale_f32``      — y = x * c          (map)
* ``relu_f32``       — y = max(x, 0)      (map)
* ``square_f32``     — y = x * x          (map)
* ``threshold_mask`` — y = (x > c) ? x : 0  (filter as dense mask)
* ``delay_filter_u32`` — the DelayedFlights predicate on packed records
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.chacha20.common import keystream_vectors

U32 = jnp.uint32
F32 = jnp.float32


def _bitcast_f32(words_u32):
    return jax.lax.bitcast_convert_type(words_u32, F32)


def _bitcast_u32(x_f32):
    return jax.lax.bitcast_convert_type(x_f32, U32)


def _op_identity(x, c):
    return x


def _op_scale_f32(x, c):
    return _bitcast_u32(_bitcast_f32(x) * c)


def _op_relu_f32(x, c):
    return _bitcast_u32(jnp.maximum(_bitcast_f32(x), 0.0))


def _op_square_f32(x, c):
    f = _bitcast_f32(x)
    return _bitcast_u32(f * f)


def _op_threshold_mask(x, c):
    f = _bitcast_f32(x)
    return _bitcast_u32(jnp.where(f > c, f, 0.0))


def _op_delay_filter_u32(x, c):
    # DelayedFlights: records are (rows,16) u32 with word 1 = delay minutes;
    # keep the record (dense mask) iff delay > c.
    delay = x[:, 1:2].astype(jnp.int32)
    keep = delay > jnp.int32(c)
    return jnp.where(keep, x, jnp.zeros_like(x))


OPS: Dict[str, Callable] = {
    "identity": _op_identity,
    "scale_f32": _op_scale_f32,
    "relu_f32": _op_relu_f32,
    "square_f32": _op_square_f32,
    "threshold_mask": _op_threshold_mask,
    "delay_filter_u32": _op_delay_filter_u32,
}


def _enclave_rows_kernel(kin_ref, kout_ref, nonce_ref, ctr_ref,
                         nonce_out_ref, ctr_out_ref, data_ref,
                         out_ref, *, op: str, const: float):
    """Per-row (key, nonce, counter) variant: the window-batched executor.

    Every VMEM row is one cipher block carrying its own key/nonce/counter
    columns, so a whole window of chunks (each chunk = a run of rows
    sharing its nonce, counters 1..n_blocks) is ONE grid sweep — the
    batched sibling of ``_enclave_kernel``, with the same VMEM-confined
    plaintext guarantee: decrypt, operator, re-encrypt never leave the
    tile.  The outbound keystream has its own (nonce, counter) columns:
    in steady state they equal the inbound ones, but a fault-tolerant
    re-execution must re-seal under a FRESH counter block (the inbound
    coordinates were already spent on ``kout`` by the first dispatch),
    so the re-encrypt coordinates are independent inputs.
    """
    kin = [kin_ref[:, i] for i in range(8)]        # 8 x (rows,)
    kout = [kout_ref[:, i] for i in range(8)]
    nonce = [nonce_ref[:, i] for i in range(3)]    # 3 x (rows,)
    counters = ctr_ref[...]                        # (rows,)
    nonce_out = [nonce_out_ref[:, i] for i in range(3)]
    counters_out = ctr_out_ref[...]

    # ---- decrypt (plaintext exists only from here ...)
    ks_in = keystream_vectors(kin, nonce, counters)
    pt = data_ref[...] ^ jnp.stack(ks_in, axis=-1)
    # ---- the enclaved operator
    y = OPS[op](pt, const)
    # ---- re-encrypt (... to here — never written to HBM)
    ks_out = keystream_vectors(kout, nonce_out, counters_out)
    out_ref[...] = y ^ jnp.stack(ks_out, axis=-1)


@functools.partial(jax.jit, static_argnames=("op", "const", "block_rows",
                                             "interpret"))
def enclave_apply_rows(keys_in: jax.Array, keys_out: jax.Array,
                       nonces: jax.Array, counters: jax.Array,
                       data_rows: jax.Array, *, op: str = "identity",
                       const: float = 0.0, block_rows: int = 256,
                       interpret: bool = True,
                       nonces_out: jax.Array = None,
                       counters_out: jax.Array = None) -> jax.Array:
    """Apply ``op`` to ciphertext rows with per-row cipher parameters.

    data_rows: (R, 16) u32 ciphertext; keys_in/keys_out: (R, 8) u32;
    nonces: (R, 3) u32; counters: (R,) u32.  R % block_rows == 0.  Row r
    is decrypted under (keys_in[r], nonces[r], counters[r]), transformed,
    and re-encrypted under keys_out[r] at the same (nonce, counter) —
    unless ``nonces_out``/``counters_out`` are given, in which case the
    re-encrypt uses those coordinates instead (the fault-tolerance
    replay path: a retried row must never re-spend a (key, nonce,
    counter) triple already used on the outbound key).
    """
    R = data_rows.shape[0]
    assert R % block_rows == 0, (R, block_rows)
    if nonces_out is None:
        nonces_out = nonces
    if counters_out is None:
        counters_out = counters
    grid = (R // block_rows,)
    return pl.pallas_call(
        functools.partial(_enclave_rows_kernel, op=op, const=const),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(data_rows.shape, U32),
        interpret=interpret,
    )(keys_in.astype(U32), keys_out.astype(U32), nonces.astype(U32),
      counters.astype(U32), nonces_out.astype(U32),
      counters_out.astype(U32), data_rows)


def _enclave_kernel(kin_ref, kout_ref, nonce_ref, ctr_ref, data_ref, out_ref,
                    *, op: str, const: float, block_rows: int):
    pid = pl.program_id(0)
    base = ctr_ref[0, 0] + (pid * block_rows).astype(U32)
    counters = base + jax.lax.broadcasted_iota(U32, (block_rows,), 0)
    nonce = [nonce_ref[0, i] for i in range(3)]

    # ---- decrypt (plaintext exists only from here ...)
    ks_in = keystream_vectors([kin_ref[0, i] for i in range(8)], nonce,
                              counters)
    pt = data_ref[...] ^ jnp.stack(ks_in, axis=-1)
    # ---- the enclaved operator
    y = OPS[op](pt, const)
    # ---- re-encrypt (... to here — never written to HBM)
    ks_out = keystream_vectors([kout_ref[0, i] for i in range(8)], nonce,
                               counters)
    out_ref[...] = y ^ jnp.stack(ks_out, axis=-1)


@functools.partial(jax.jit, static_argnames=("op", "const", "block_rows",
                                             "interpret"))
def enclave_apply(key_in: jax.Array, key_out: jax.Array, nonce: jax.Array,
                  counter0, data_blocks: jax.Array, *, op: str = "identity",
                  const: float = 0.0, block_rows: int = 512,
                  interpret: bool = True) -> jax.Array:
    """Apply `op` to AEAD-CTR ciphertext blocks without exposing plaintext.

    data_blocks: (N, 16) u32 ciphertext under (key_in, nonce, counter0).
    Returns ciphertext of op(plaintext) under (key_out, nonce, counter0).
    """
    N = data_blocks.shape[0]
    assert N % block_rows == 0, (N, block_rows)
    grid = (N // block_rows,)
    return pl.pallas_call(
        functools.partial(_enclave_kernel, op=op, const=const,
                          block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(data_blocks.shape, U32),
        interpret=interpret,
    )(key_in.reshape(1, 8).astype(U32), key_out.reshape(1, 8).astype(U32),
      nonce.reshape(1, 3).astype(U32), jnp.asarray(counter0, U32).reshape(1, 1),
      data_blocks)
