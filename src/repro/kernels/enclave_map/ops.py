"""Public op wrapper for the enclave executor kernel."""
from __future__ import annotations

import jax

from repro.kernels.enclave_map.enclave_map import enclave_apply, OPS  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def enclave_map(key_in, key_out, nonce, counter0, data_blocks, *, op,
                const=0.0, block_rows: int = 512):
    return enclave_apply(key_in, key_out, nonce, counter0, data_blocks,
                         op=op, const=const, block_rows=block_rows,
                         interpret=not _on_tpu())
