"""Public op wrappers for the enclave executor kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.enclave_map.enclave_map import (  # noqa: F401
    OPS, enclave_apply, enclave_apply_rows)
from repro.obs.metrics import REGISTRY as _METRICS

# each wrapper call launches exactly one jitted enclave program — count
# it here, in the eager wrapper, never inside the traced kernel
_DISPATCHES = _METRICS.counter("device.dispatches")
_DISP_MAP = _METRICS.counter("device.dispatches.enclave_map")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def enclave_map(key_in, key_out, nonce, counter0, data_blocks, *, op,
                const=0.0, block_rows: int = 512):
    _DISPATCHES.inc()
    _DISP_MAP.inc()
    return enclave_apply(key_in, key_out, nonce, counter0, data_blocks,
                         op=op, const=const, block_rows=block_rows,
                         interpret=not _on_tpu())


def enclave_map_rows(keys_in, keys_out, nonces, counters, rows, *, op,
                     const=0.0, block_rows: int = 256,
                     nonces_out=None, counters_out=None):
    """Per-row fused decrypt->op->encrypt over (R, 16) u32 rows.

    keys_in/keys_out: (8,) shared or (R, 8) per-row (mixed-epoch windows
    carry per-row keys); nonces: (R, 3); counters: (R,).  Auto-pads R to
    a tile multiple (padded tail rows use zero cipher parameters and are
    sliced off).  One grid sweep processes a whole window of chunks.
    ``nonces_out``/``counters_out`` re-encrypt under separate outbound
    coordinates (fault-tolerant re-execution: the inbound coordinates
    were already spent on the outbound key by the first dispatch).
    """
    _DISPATCHES.inc()
    _DISP_MAP.inc()
    R = rows.shape[0]
    ones = jnp.ones((R, 1), jnp.uint32)
    kin = keys_in.reshape(1, 8) * ones if keys_in.ndim == 1 else keys_in
    kout = keys_out.reshape(1, 8) * ones if keys_out.ndim == 1 else keys_out
    if nonces_out is None:
        nonces_out = nonces
    if counters_out is None:
        counters_out = counters
    pad = (-R) % block_rows
    if pad:
        kin = jnp.pad(kin, ((0, pad), (0, 0)))
        kout = jnp.pad(kout, ((0, pad), (0, 0)))
        nonces = jnp.pad(nonces, ((0, pad), (0, 0)))
        counters = jnp.pad(counters, (0, pad))
        nonces_out = jnp.pad(nonces_out, ((0, pad), (0, 0)))
        counters_out = jnp.pad(counters_out, (0, pad))
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    out = enclave_apply_rows(kin, kout, nonces, counters, rows, op=op,
                             const=const, block_rows=block_rows,
                             interpret=not _on_tpu(),
                             nonces_out=nonces_out,
                             counters_out=counters_out)
    return out[:R]
