"""Pure-jnp oracle for the enclave_map kernel: decrypt, op, re-encrypt —
with plaintext as a visible intermediate (this is exactly the 'encrypted'
mode of the paper's Fig. 6, vs. the kernel's 'enclave' mode)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.crypto import chacha20
from repro.kernels.enclave_map.enclave_map import OPS


def enclave_apply_ref(key_in, key_out, nonce, counter0, data_blocks, *,
                      op="identity", const=0.0):
    flat = data_blocks.reshape(-1)
    pt = chacha20.decrypt_words(key_in, nonce, flat, counter0=int(counter0))
    y = OPS[op](pt.reshape(-1, 16), const)
    ct = chacha20.encrypt_words(key_out, nonce, y.reshape(-1),
                                counter0=int(counter0))
    return ct.reshape(data_blocks.shape)


def enclave_apply_rows_ref(keys_in, keys_out, nonces, counters, data_rows, *,
                           op="identity", const=0.0,
                           nonces_out=None, counters_out=None):
    """Row-batched oracle: per-row (key, nonce, counter) decrypt -> op ->
    re-encrypt, mirroring ``enclave_apply_rows`` (plaintext visible)."""
    ks_in = chacha20.chacha20_block_rows(keys_in, nonces, counters)
    pt = data_rows ^ ks_in
    y = OPS[op](pt, const)
    ks_out = chacha20.chacha20_block_rows(
        keys_out,
        nonces if nonces_out is None else nonces_out,
        counters if counters_out is None else counters_out)
    return y ^ ks_out
