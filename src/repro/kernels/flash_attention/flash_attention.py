"""Pallas TPU kernel: causal flash attention forward with block skipping.

Grid: (B, H, num_q_blocks).  Each program streams KV blocks for one query
block with the online-softmax recurrence in VMEM scratch.  Causality is
exploited *structurally*: the fori_loop upper bound is derived from the
query block index, so fully-masked KV blocks are never computed — this is
the 2x attention-FLOP saving over the lax.scan formulation (which must scan
all KV blocks with masking; see EXPERIMENTS.md §Perf).

BlockSpecs: q (1,1,Bq,D), k/v (1,1,Skv,D) resident per (b,h) program —
for Skv=4k, D=128, bf16 that is 2 x 1 MiB of VMEM; Bq=512 keeps the scratch
(acc/m/l) under 0.5 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30
F32 = jnp.float32


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, q_chunk: int, kv_chunk: int,
                  scale: float, causal: bool):
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(F32)                     # (Bq, D)
    Skv = k_ref.shape[2]
    n_kv = Skv // kv_chunk
    # causal: only kv blocks with start <= last query position
    hi = jnp.minimum(((iq + 1) * q_chunk + kv_chunk - 1) // kv_chunk,
                     n_kv) if causal else n_kv
    q_pos = iq * q_chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_chunk, 1), 0)

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[0, 0], (j * kv_chunk, 0),
                                  (kv_chunk, k_ref.shape[3])).astype(F32)
        v = jax.lax.dynamic_slice(v_ref[0, 0], (j * kv_chunk, 0),
                                  (kv_chunk, v_ref.shape[3])).astype(F32)
        s = jnp.dot(q, k.T, preferred_element_type=F32) * scale  # (Bq, Bkv)
        if causal:
            kv_pos = j * kv_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (1, kv_chunk), 1)
            s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.dot(p, v, preferred_element_type=F32)
        return m_new, l_new, acc_new

    D = q_ref.shape[3]
    init = (jnp.full((q_chunk, 1), NEG_INF, F32),
            jnp.zeros((q_chunk, 1), F32),
            jnp.zeros((q_chunk, D), F32))
    m, l, acc = jax.lax.fori_loop(0, hi, body, init)
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "kv_chunk",
                                             "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                         kv_chunk: int = 512, interpret: bool = True):
    """q,k,v: (B, H, S, D) (head-major for clean BlockSpecs)."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0
    grid = (B, H, Sq // q_chunk)
    scale = 1.0 / np.sqrt(D)
    return pl.pallas_call(
        functools.partial(_flash_kernel, q_chunk=q_chunk, kv_chunk=kv_chunk,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_chunk, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_chunk, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
