"""Public op: flash attention accepting the model's (B,S,H,D) layout."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 512):
    """q,k,v: (B, S, H, D) with kv already expanded to H heads."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, q_chunk=q_chunk,
                               kv_chunk=kv_chunk, interpret=not _on_tpu())
    return out.swapaxes(1, 2)
