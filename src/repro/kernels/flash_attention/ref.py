"""Oracle: naive full-materialization causal attention (B,H,S,D layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True):
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("BHqD,BHkD->BHqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("BHqk,BHkD->BHqD", p,
                      v.astype(jnp.float32)).astype(q.dtype)
