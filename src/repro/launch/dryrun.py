import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------------
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, ARCH_IDS, cell_supported, get_run_config)
from repro.configs.base import RunConfig, ShardingConfig
from repro.dist.meshctx import MeshContext
from repro.launch import hloanalysis
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.optim import make_optimizer, opt_state_shardings
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.steps import make_train_step

# TPU v5e hardware constants (per chip) — see EXPERIMENTS.md §Roofline.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------


def input_specs(run: RunConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one step of the cell's kind (train/prefill/decode)."""
    cfg, shape = run.model, run.shape
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    elif shape.kind == "prefill":
        out = {"tokens": sds((B, S), i32)}
    else:  # decode: one new token against a cache of S
        out = {"tokens": sds((B, 1), i32)}
    if cfg.frontend == "vision_patches" and shape.kind != "decode":
        out["patches"] = sds((B, 256, cfg.frontend_dim), jnp.bfloat16)
    elif cfg.frontend == "audio_frames" and shape.kind != "decode":
        out["frames"] = sds((B, S, cfg.frontend_dim), jnp.bfloat16)
    return out


def _rules_for_shape(run: RunConfig) -> ShardingConfig:
    """Per-shape sharding-rule adjustments (SP for long context, decode KV)."""
    sc = run.sharding
    if run.shape.name == "long_500k":
        sc = sc.with_rule("kv_seq", ("data", "model"))
        sc = sc.with_rule("seq", ("data",))
    elif run.shape.kind == "decode":
        sc = sc.with_rule("kv_seq", ("model",))
    return sc


def make_ctx(run: RunConfig, mesh) -> MeshContext:
    sc = _rules_for_shape(run)
    return MeshContext(mesh=mesh, rules=sc.lookup(),
                       allow_uneven=sc.allow_uneven)


def _batch_shardings(run: RunConfig, ctx: MeshContext, specs):
    def shard(name, s):
        if name in ("tokens", "labels") and s.shape[0] > 1:
            logical = ["batch"] + [None] * (len(s.shape) - 1)
        elif name in ("patches", "frames"):
            logical = ["batch"] + [None] * (len(s.shape) - 1)
        else:  # single-sequence long-context: shard seq
            logical = [None, "seq"] if len(s.shape) == 2 else \
                [None] * len(s.shape)
        return ctx.sharding(logical, s.shape)
    return {k: shard(k, v) for k, v in specs.items()}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_cell(run: RunConfig, mesh) -> Tuple[Any, Any, MeshContext]:
    """Returns (lowered, donated_memory_note, ctx)."""
    ctx = make_ctx(run, mesh)
    cfg = run.model
    p_abs = model_api.abstract_params(cfg)
    p_shard = model_api.param_shardings(cfg, ctx)
    batch_abs = input_specs(run)
    b_shard = _batch_shardings(run, ctx, batch_abs)

    if run.shape.kind == "train":
        step_fn, opt = make_train_step(run, ctx)
        o_abs = jax.eval_shape(opt.init, p_abs)
        o_shard = opt_state_shardings(opt, p_abs, p_shard, ctx)
        fn = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard, None),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        lowered = fn.lower(p_abs, o_abs, batch_abs,
                           jax.ShapeDtypeStruct((), jnp.int32))
    elif run.shape.kind == "prefill":
        step_fn = make_prefill_step(run, ctx, max_seq=run.shape.seq_len)
        c_shard = model_api.cache_shardings(cfg, run.shape.global_batch,
                                            run.shape.seq_len, ctx)
        fn = jax.jit(step_fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(None, c_shard))
        lowered = fn.lower(p_abs, batch_abs)
    else:  # decode
        step_fn = make_decode_step(run, ctx)
        B, S = run.shape.global_batch, run.shape.seq_len
        c_abs = model_api.abstract_cache(cfg, B, S)
        c_shard = model_api.cache_shardings(cfg, B, S, ctx)
        fn = jax.jit(step_fn,
                     in_shardings=(p_shard, b_shard["tokens"], None, c_shard),
                     out_shardings=(None, None, c_shard),
                     donate_argnums=(3,))
        lowered = fn.lower(p_abs, batch_abs["tokens"],
                           jax.ShapeDtypeStruct((), jnp.int32), c_abs)
    return lowered, None, ctx


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def model_flops(run: RunConfig) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params."""
    n = run.model.active_param_count()
    if run.shape.kind == "train":
        return 6.0 * n * run.shape.tokens
    if run.shape.kind == "prefill":
        return 2.0 * n * run.shape.tokens
    return 2.0 * n * run.shape.global_batch  # decode: one token per sequence


def roofline(run: RunConfig, analysis: hloanalysis.Analysis,
             nchips: int) -> Dict[str, Any]:
    t_compute = analysis.flops / PEAK_FLOPS           # per-chip program
    t_mem = analysis.bytes / HBM_BW
    t_coll = analysis.collective_bytes / ICI_BW
    terms = {"t_compute_s": t_compute, "t_mem_s": t_mem, "t_coll_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(run) / nchips                    # per-chip useful flops
    return {
        **terms,
        "dominant": dom,
        "hlo_flops_per_chip": analysis.flops,
        "hlo_bytes_per_chip": analysis.bytes,
        "collective_bytes_per_chip": analysis.collective_bytes,
        "collective_by_kind": analysis.collective_by_kind,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": (mf / analysis.flops) if analysis.flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / max(
            t_compute, t_mem, t_coll) if max(t_compute, t_mem, t_coll) else 0.0,
    }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: str = OUT_DIR, force: bool = False,
             save_hlo: bool = False,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    ok, reason = cell_supported(arch, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "timestamp": time.time()}
    if not ok:
        rec.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        return rec

    run = get_run_config(arch, shape, **(overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = mesh.size
    t0 = time.time()
    try:
        lowered, _, ctx = lower_cell(run, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        analysis = hloanalysis.analyze(hlo)
        if save_hlo:
            with open(path.replace(".json", ".hlo"), "w") as f:
                f.write(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            nchips=nchips,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (mem.argument_size_in_bytes
                                        + mem.temp_size_in_bytes
                                        + mem.output_size_in_bytes
                                        - mem.alias_size_in_bytes),
            },
            xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed")
                      if k in cost},
            roofline=roofline(run, analysis, nchips),
            collective_count=analysis.collective_count,
        )
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def fmt_cell(rec: Dict[str, Any]) -> str:
    if rec["status"] == "skipped":
        return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:11s} "
                f"SKIP ({rec['reason'][:50]}...)")
    if rec["status"] == "error":
        return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:11s} "
                f"ERROR {rec['error'][:80]}")
    r = rec["roofline"]
    peak = rec["memory"]["peak_estimate_bytes"] / 1e9
    return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:11s} ok "
            f"compile={rec['compile_s']:7.1f}s mem={peak:7.2f}GB "
            f"tc={r['t_compute_s']:.3e} tm={r['t_mem_s']:.3e} "
            f"tx={r['t_coll_s']:.3e} dom={r['dominant'][2:]:8s} "
            f"roofline={r['roofline_fraction']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir=args.out,
                               force=args.force, save_hlo=args.save_hlo)
                print(fmt_cell(rec), flush=True)
                n_err += rec["status"] == "error"
    if n_err:
        raise SystemExit(f"{n_err} cells failed")


if __name__ == "__main__":
    main()
