"""Post-partitioning HLO analysis: loop-aware FLOPs, bytes, collective bytes.

XLA's built-in ``HloCostAnalysis`` (surfaced as ``compiled.cost_analysis()``)
visits each ``while`` body exactly once — a scan over 61 layers reports the
FLOPs of one layer.  Our frameworks scan everything (layers, attention
chunks, loss chunks, microbatches), so this module re-derives the roofline
inputs from ``compiled.as_text()`` with loop trip counts applied:

* ``flops``            — 2 * prod(result_shape) * prod(contracting dims) per
                         ``dot``; convolutions are counted analogously.
* ``bytes``            — Σ over non-fusion-internal instructions of
                         (operand bytes + result bytes).  Fusion internals are
                         skipped: on TPU a fusion's intermediates live in
                         VMEM/registers, so fusion boundaries approximate HBM
                         traffic.  This is a *model*, stated as such.
* ``collective_bytes`` — Σ operand bytes of all-reduce / all-gather /
                         reduce-scatter / all-to-all / collective-permute.

All sums are per-device (the partitioned module is per-device); multiply by
chip count for fleet totals.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Header: `%name (args...) -> rettype {` — args may contain nested parens, so
# just take the identifier before the first '('.
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w\.\-,%\s]+)\}?")
_CONST_CMP_RE = re.compile(r"compare\([^)]*\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instruction:
    name: str
    opcode: str
    result_type: str
    operand_names: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # instr name -> type

    def operand_types(self, ins: Instruction) -> List[str]:
        return [self.types.get(n, "") for n in ins.operand_names]


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OPCODE_RE = re.compile(r"^([\w\-]+)\(")


def _matching_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instruction(line: str) -> Optional[Tuple[str, str, str, str, str]]:
    """-> (name, result_type, opcode, args, tail) or None."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), _COMMENT_RE.sub("", m.group(2)).strip()
    if rest.startswith("("):           # tuple result type
        end = _matching_paren(rest, 0)
        rtype = rest[:end + 1]
        rest = rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        rest = rest[sp + 1:].strip()
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    open_idx = len(opcode)
    close = _matching_paren(rest, open_idx)
    args = rest[open_idx + 1:close]
    tail = rest[close + 1:]
    return name, rtype, opcode, args, tail


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        bare = stripped.strip()
        if cur is None or bare.endswith("{"):
            hdr = _COMP_HEADER_RE.match(bare)
            if hdr and ("->" in bare):
                cur = Computation(name=hdr.group(1))
                comps[cur.name] = cur
                continue
        if bare == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_instruction(stripped)
        if parsed is None:
            continue
        iname, rtype, opcode, args, tail = parsed
        operand_names = re.findall(r"%([\w\.\-]+)", args)
        instr = Instruction(iname, opcode, rtype, operand_names,
                            stripped)
        cur.instructions.append(instr)
        cur.types[iname] = rtype
    return comps


def _dims_of(type_str: str) -> Tuple[List[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], "f32"
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


def _dot_flops(instr: Instruction, op_types: List[str]) -> float:
    dims_out, _ = _dims_of(instr.result_type)
    n_out = 1
    for d in dims_out:
        n_out *= d
    lhs_dims, _ = _dims_of(op_types[0]) if op_types else ([], "")
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * n_out * contract


def _conv_flops(instr: Instruction, op_types: List[str]) -> float:
    # rough: 2 * out_elems * (in_channels * kernel_spatial)
    dims_out, _ = _dims_of(instr.result_type)
    n_out = 1
    for d in dims_out:
        n_out *= d
    kdims, _ = _dims_of(op_types[1]) if len(op_types) > 1 else ([], "")
    k = 1
    for d in kdims[:-1]:
        k *= d
    return 2.0 * n_out * k


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "copy-start", "copy-done",
                   "while", "conditional", "call", "optimization-barrier",
                   "partition-id", "replica-id"}


def _instr_bytes(ins: Instruction, op_types: List[str]) -> float:
    """HBM-traffic model for one instruction (see module docstring).

    Slicing/scatter ops touch only the slice, not the whole operand:
    * dynamic-slice / gather / slice: result + index operands;
    * dynamic-update-slice: 2x the update operand (read + write), indices;
    * scatter: 2x updates + indices (in-place aliasing).
    """
    rb = _shape_bytes(ins.result_type)
    if ins.opcode in ("dynamic-slice", "gather", "slice"):
        idx = sum(_shape_bytes(t) for t in op_types[1:])
        return rb + idx
    if ins.opcode == "dynamic-update-slice":
        upd = _shape_bytes(op_types[1]) if len(op_types) > 1 else rb
        idx = sum(_shape_bytes(t) for t in op_types[2:])
        return 2 * upd + idx
    if ins.opcode == "scatter":
        upd = _shape_bytes(op_types[2]) if len(op_types) > 2 else rb
        idx = _shape_bytes(op_types[1]) if len(op_types) > 1 else 0
        return 2 * upd + idx
    return rb + sum(_shape_bytes(t) for t in op_types)


@dataclass
class Analysis:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    dot_flops_by_comp: Dict[str, float] = field(default_factory=dict)


def _trip_count(cond: Computation) -> int:
    """Extract the while trip count from the condition computation.

    Standard lax.scan lowering: condition is `param < constant(N)` (possibly
    behind a wrapped-compare fusion).  Heuristic: the largest integer
    constant in the condition computation is the trip count.
    """
    best = 1
    for ins in cond.instructions:
        m = re.search(r"constant\((-?\d+)\)", ins.raw)
        if m:
            best = max(best, int(m.group(1)))
    return best


def analyze(hlo: str) -> Analysis:
    comps = parse_module(hlo)

    # map: computation -> list of (callee, multiplier)
    fusion_bodies = set()
    calls: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for c in comps.values():
        for ins in c.instructions:
            if ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
                if m:
                    fusion_bodies.add(m.group(1))
            elif ins.opcode == "while":
                m = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
                b = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                if m and b and m.group(1) in comps:
                    k = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                                  ins.raw)
                    tc = int(k.group(1)) if k else _trip_count(comps[m.group(1)])
                    calls[c.name].append((b.group(1), tc))
                    calls[c.name].append((m.group(1), tc))
            elif ins.opcode in ("call", "custom-call", "conditional"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", ins.raw):
                    calls[c.name].append((m.group(1), 1))
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.raw)
                if m:
                    for b in m.group(1).split(","):
                        calls[c.name].append((b.strip().lstrip("%"), 1))
            elif ins.opcode in ("reduce", "map", "scatter", "sort",
                                "reduce-window", "select-and-scatter",
                                "all-reduce", "reduce-scatter"):
                m = re.search(r"to_apply=%?([\w\.\-]+)", ins.raw)
                if m:
                    fusion_bodies.add(m.group(1))  # tiny reducers: ignore

    # compute multiplier per computation by walking from entry
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: computation named like main
        for name in comps:
            if "main" in name:
                entry = name
                break
    mult: Dict[str, float] = defaultdict(float)

    def walk(name: str, m: float, seen_depth=0):
        if name not in comps or seen_depth > 64:
            return
        mult[name] += m
        for callee, tc in calls.get(name, ()):  # multiply by trip counts
            walk(callee, m * tc, seen_depth + 1)

    if entry:
        walk(entry, 1.0)

    out = Analysis()
    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fusion_bodies:
            continue
        for ins in c.instructions:
            op_types = c.operand_types(ins)
            if ins.opcode == "dot":
                f = _dot_flops(ins, op_types) * m
                out.flops += f
                out.dot_flops_by_comp[cname] = \
                    out.dot_flops_by_comp.get(cname, 0.0) + f
            elif ins.opcode == "convolution":
                out.flops += _conv_flops(ins, op_types) * m
            if ins.opcode in _SKIP_BYTES_OPS:
                continue
            out.bytes += _instr_bytes(ins, op_types) * m
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
            if base in _COLLECTIVES:
                cb = sum(_shape_bytes(t) for t in op_types)
                if cb == 0:
                    cb = _shape_bytes(ins.result_type)
                out.collective_bytes += cb * m
                out.collective_by_kind[base] = \
                    out.collective_by_kind.get(base, 0.0) + cb * m
                out.collective_count += 1
    return out
