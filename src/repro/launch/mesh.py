"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The target deployment mesh.

    Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes ("data", "model").
    Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") —
    the "pod" axis carries pure data parallelism with hierarchical gradient
    reduction (reduce-scatter intra-pod, all-reduce across the DCN/pod axis).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_devices: int = 0) -> jax.sharding.Mesh:
    """A small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))
