from repro.models.api import (  # noqa: F401
    abstract_cache,
    abstract_params,
    cache_shardings,
    cache_template,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_shardings,
    param_template,
    prefill,
)
