"""Unified model API over all assigned architecture families.

Public surface (used by train/serve/launch):

* ``param_template(cfg)``      -> nested dict of ParamSpec
* ``abstract_params(cfg)``     -> ShapeDtypeStruct pytree (dry-run, no alloc)
* ``init_params(cfg, key)``    -> real params (smoke tests / examples)
* ``param_shardings(cfg, ctx)``-> NamedSharding pytree
* ``forward(cfg, params, batch, ctx)``            -> (hidden, aux_loss)
* ``loss_fn(cfg, params, batch, ctx)``            -> (loss, metrics)
* ``cache_template(cfg, batch, max_seq)``; ``init_cache``; ``cache_shardings``
* ``prefill(cfg, params, batch, ctx)``            -> (last_logits, cache)
* ``decode_step(cfg, params, tokens, pos, cache, ctx)`` -> (logits, cache)

Layer stacks are ``lax.scan``-ed (homogeneous HLO regardless of depth) with
optional remat; heterogeneous families (xLSTM pairs, Zamba2 groups) scan
their homogeneous sub-stacks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.meshctx import MeshContext
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import xlstm as XL
from repro.models.layers import ParamSpec, Params

Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------


def _norm(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def _mlp_template(cfg: ModelConfig) -> Dict[str, Any]:
    t = {
        "wu": ParamSpec((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
        "wd": ParamSpec((cfg.d_ff, cfg.d_model), ("mlp", "embed")),
    }
    if cfg.mlp_type == "swiglu":
        t["wg"] = ParamSpec((cfg.d_model, cfg.d_ff), ("embed", "mlp"))
    return t


def _apply_mlp(p, h, cfg, ctx):
    if cfg.mlp_type == "swiglu":
        return L.swiglu(h, p["wg"], p["wu"], p["wd"], ctx)
    u = jnp.einsum("...E,EF->...F", h, p["wu"])
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(h.dtype)
    u = ctx.constrain(u, ("batch", "seq", "mlp")) if u.ndim == 3 else u
    return jnp.einsum("...F,FE->...E", u, p["wd"])


def _dense_layer_template(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": _norm(cfg.d_model),
        "attn": L.attention_template(cfg),
        "ln2": _norm(cfg.d_model),
        "mlp": _mlp_template(cfg),
    }


def _moe_layer_template(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": _norm(cfg.d_model),
        "attn": L.attention_template(cfg),
        "ln2": _norm(cfg.d_model),
        "moe": MOE.moe_template(cfg),
    }


def _xlstm_pair_template(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln_m": _norm(cfg.d_model),
        "mlstm": XL.mlstm_template(cfg),
        "ln_s": _norm(cfg.d_model),
        "slstm": XL.slstm_template(cfg),
    }


def _mamba_layer_template(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": _norm(cfg.d_model), "mamba": M2.mamba2_template(cfg)}


def param_template(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    t: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": _norm(d),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))
    if cfg.frontend == "vision_patches":
        t["frontend_proj"] = ParamSpec((cfg.frontend_dim, d), (None, "embed"))
    elif cfg.frontend == "audio_frames":
        t["frontend_proj"] = ParamSpec((cfg.frontend_dim, d), (None, "embed"))

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        t["layers"] = L.stack_template(_dense_layer_template(cfg),
                                       cfg.num_layers)
    elif fam == "moe":
        t["layers"] = L.stack_template(_moe_layer_template(cfg),
                                       cfg.num_layers)
    elif fam == "ssm":  # xLSTM
        assert cfg.num_layers % 2 == 0
        t["layers"] = L.stack_template(_xlstm_pair_template(cfg),
                                       cfg.num_layers // 2)
    elif fam == "hybrid":  # Zamba2
        t["layers"] = L.stack_template(_mamba_layer_template(cfg),
                                       cfg.num_layers)
        t["shared_attn"] = {
            "ln1": _norm(d),
            "attn": L.attention_template(cfg),
            "ln2": _norm(d),
            "mlp": _mlp_template(cfg),
        }
    else:
        raise ValueError(f"unknown family {fam!r}")
    return t


def abstract_params(cfg: ModelConfig) -> Params:
    return L.abstract_from_template(param_template(cfg))


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    return L.init_from_template(param_template(cfg), key)


def param_shardings(cfg: ModelConfig, ctx: MeshContext) -> Params:
    return L.shardings_from_template(param_template(cfg), ctx)


# ---------------------------------------------------------------------------
# Hybrid (Zamba2) group geometry
# ---------------------------------------------------------------------------


def _hybrid_groups(cfg: ModelConfig):
    """[(start, size, has_attn_after), ...] — shared attn after each full group."""
    period = cfg.attn_every or cfg.num_layers
    groups = []
    i = 0
    while i < cfg.num_layers:
        size = min(period, cfg.num_layers - i)
        groups.append((i, size, size == period))
        i += size
    return groups


def num_shared_attn(cfg: ModelConfig) -> int:
    return sum(1 for _, _, a in _hybrid_groups(cfg) if a)


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------


def _dense_block(p, x, cfg, ctx, positions, remat_policy):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    x = x + L.mha(p["attn"], h, cfg, ctx, positions=positions)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    x = x + _apply_mlp(p["mlp"], h, cfg, ctx)
    # "seq_res": optional Megatron-style sequence-parallel residual stream —
    # shards the (B,S,E) residual (and its remat stash) over the model axis.
    return ctx.constrain(x, ("batch", "seq_res", "embed")), jnp.zeros((), jnp.float32)


def _moe_block(p, x, cfg, ctx, positions, remat_policy):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    x = x + L.mha(p["attn"], h, cfg, ctx, positions=positions)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    out, aux = MOE.moe_ffn(p["moe"], h, cfg, ctx)
    x = x + out
    return ctx.constrain(x, ("batch", "seq_res", "embed")), aux


def _xlstm_pair_block(p, x, cfg, ctx, positions, remat_policy):
    h = L.rms_norm(x, p["ln_m"], cfg.rms_eps)
    x = x + XL.mlstm_forward(p["mlstm"], h, cfg, ctx)
    h = L.rms_norm(x, p["ln_s"], cfg.rms_eps)
    x = x + XL.slstm_forward(p["slstm"], h, cfg, ctx)
    return x, jnp.zeros((), jnp.float32)


def _mamba_block(p, x, cfg, ctx, positions, remat_policy):
    h = L.rms_norm(x, p["ln"], cfg.rms_eps)
    x = x + M2.mamba2_forward(p["mamba"], h, cfg, ctx)
    return ctx.constrain(x, ("batch", "seq_res", "embed")), jnp.zeros((), jnp.float32)


_BLOCK_FNS = {
    "dense": _dense_block, "vlm": _dense_block, "audio": _dense_block,
    "moe": _moe_block, "ssm": _xlstm_pair_block, "hybrid": _mamba_block,
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "selective":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full"


def _scan_stack(block_fn, stacked_params, x, remat: str):
    ck = _maybe_remat(block_fn, remat)
    x, auxs = jax.lax.scan(lambda c, p: ck(p, c), x, stacked_params)
    return x, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Embedding / frontend
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params: Params, batch: Batch,
           ctx: MeshContext) -> jax.Array:
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = ctx.constrain(x, ("batch", "seq", "embed"))
    if cfg.frontend == "vision_patches" and "patches" in batch:
        proj = jnp.einsum("BPF,FE->BPE", batch["patches"],
                          params["frontend_proj"]).astype(x.dtype)
        P = proj.shape[1]
        x = jnp.concatenate([proj, x[:, P:]], axis=1)
    elif cfg.frontend == "audio_frames" and "frames" in batch:
        proj = jnp.einsum("BSF,FE->BSE", batch["frames"],
                          params["frontend_proj"]).astype(x.dtype)
        x = x + proj
    return x


def forward(cfg: ModelConfig, params: Params, batch: Batch, ctx: MeshContext,
            *, remat: str = "full") -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (hidden (B,S,E), aux_loss)."""
    x = _embed(cfg, params, batch, ctx)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    fam = cfg.family
    block = functools.partial(_BLOCK_FNS[fam], cfg=cfg, ctx=ctx,
                              positions=positions, remat_policy=remat)
    bf = lambda p_l, xx: block(p_l, xx)

    if fam == "hybrid":
        aux = jnp.zeros((), jnp.float32)
        for start, size, has_attn in _hybrid_groups(cfg):
            sub = jax.tree.map(lambda a: a[start:start + size],
                               params["layers"])
            x, a = _scan_stack(lambda p, xx: bf(p, xx), sub, x, remat)
            aux = aux + a
            if has_attn:
                x, _ = _dense_block(params["shared_attn"], x, cfg, ctx,
                                    positions, remat)
    else:
        x, aux = _scan_stack(lambda p, xx: bf(p, xx), params["layers"], x,
                             remat)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, aux


# ---------------------------------------------------------------------------
# Loss (streaming LM head: never materializes the full (T, V) logits)
# ---------------------------------------------------------------------------


def _lm_head_weight(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T  # (E, V)
    return params["lm_head"]


def loss_fn(cfg: ModelConfig, params: Params, batch: Batch, ctx: MeshContext,
            *, remat: str = "full", loss_chunks: int = 8,
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    hidden, aux = forward(cfg, params, batch, ctx, remat=remat)
    B, S, E = hidden.shape
    W = _lm_head_weight(cfg, params)
    labels = batch["labels"].reshape(B * S)
    h = hidden.reshape(B * S, E)
    nchunk = loss_chunks
    while (B * S) % nchunk:
        nchunk -= 1
    hc = h.reshape(nchunk, (B * S) // nchunk, E)
    lc = labels.reshape(nchunk, (B * S) // nchunk)

    def chunk_loss(carry, xs):
        hx, lx = xs
        logits = jnp.einsum("TE,EV->TV", hx, W,
                            preferred_element_type=jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[:, None].clip(0), axis=-1)[:, 0]
        valid = (lx >= 0).astype(jnp.float32)
        nll = jnp.sum((lse - gold) * valid)
        ntok = jnp.sum(valid)
        return (carry[0] + nll, carry[1] + ntok), None

    body = _maybe_remat(lambda c, xs: chunk_loss(c, xs), remat)
    (nll, ntok), _ = jax.lax.scan(lambda c, xs: body(c, xs),
                                  (jnp.zeros((), jnp.float32),
                                   jnp.zeros((), jnp.float32)),
                                  (hc, lc))
    loss = nll / jnp.maximum(ntok, 1.0)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": ntok}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def cache_template(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    fam = cfg.family
    if fam in ("dense", "vlm", "audio", "moe"):
        return {"attn": L.stack_template(
            L.attention_cache_template(cfg, batch, max_seq), cfg.num_layers)}
    if fam == "ssm":
        return {
            "mlstm": L.stack_template(XL.mlstm_cache_template(cfg, batch),
                                      cfg.num_layers // 2),
            "slstm": L.stack_template(XL.slstm_cache_template(cfg, batch),
                                      cfg.num_layers // 2),
        }
    if fam == "hybrid":
        return {
            "mamba": L.stack_template(M2.mamba2_cache_template(cfg, batch),
                                      cfg.num_layers),
            "attn": L.stack_template(
                L.attention_cache_template(cfg, batch, max_seq),
                num_shared_attn(cfg)),
        }
    raise ValueError(fam)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    return L.abstract_from_template(cache_template(cfg, batch, max_seq))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    return L.tree_map_specs(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        cache_template(cfg, batch, max_seq))


def cache_shardings(cfg: ModelConfig, batch: int, max_seq: int,
                    ctx: MeshContext) -> Params:
    return L.shardings_from_template(cache_template(cfg, batch, max_seq), ctx)


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: Params, batch: Batch, ctx: MeshContext,
            *, max_seq: Optional[int] = None) -> Tuple[jax.Array, Params]:
    """Run the full prompt, produce the cache + logits of the last position."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    x = _embed(cfg, params, batch, ctx)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    fam = cfg.family

    def attn_prefill(p, xx, write_seq):
        h = L.rms_norm(xx, p["ln1"], cfg.rms_eps)
        out, kv = L.mha(p["attn"], h, cfg, ctx, positions=positions,
                        return_kv=True, attn_impl="hier")
        xx = xx + out
        h = L.rms_norm(xx, p["ln2"], cfg.rms_eps)
        if "mlp" in p:
            xx = xx + _apply_mlp(p["mlp"], h, cfg, ctx)
        else:
            mo, _ = MOE.moe_ffn(p["moe"], h, cfg, ctx)
            xx = xx + mo
        k, v = kv
        if write_seq < max_seq:
            zk = jnp.zeros((B, max_seq, *k.shape[2:]), k.dtype)
            k = jax.lax.dynamic_update_slice(zk, k, (0, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(jnp.zeros_like(zk), v,
                                             (0, 0, 0, 0))
        return ctx.constrain(xx, ("batch", "seq", "embed")), {"k": k, "v": v}

    if fam in ("dense", "vlm", "audio", "moe"):
        def body(carry, p_l):
            xx, cache_l = attn_prefill(p_l, carry, S)
            return xx, cache_l
        x, caches = jax.lax.scan(body, x, params["layers"])
        cache = {"attn": caches}
    elif fam == "ssm":
        def body(carry, p_l):
            xx = carry
            h = L.rms_norm(xx, p_l["ln_m"], cfg.rms_eps)
            ym, mstate = XL.mlstm_forward_with_state(p_l["mlstm"], h, cfg, ctx)
            xx = xx + ym
            h = L.rms_norm(xx, p_l["ln_s"], cfg.rms_eps)
            ys, sstate = XL.slstm_forward_with_state(p_l["slstm"], h, cfg, ctx)
            xx = xx + ys
            return xx, (mstate, sstate)
        x, (mstates, sstates) = jax.lax.scan(body, x, params["layers"])
        cache = {"mlstm": mstates, "slstm": sstates}
    elif fam == "hybrid":
        mcaches, acaches = [], []
        for start, size, has_attn in _hybrid_groups(cfg):
            sub = jax.tree.map(lambda a: a[start:start + size],
                               params["layers"])

            def mbody(carry, p_l):
                xx = carry
                h = L.rms_norm(xx, p_l["ln"], cfg.rms_eps)
                y, st = M2.mamba2_forward_with_state(p_l["mamba"], h, cfg, ctx)
                return ctx.constrain(xx + y, ("batch", "seq", "embed")), st
            x, st = jax.lax.scan(mbody, x, sub)
            mcaches.append(st)
            if has_attn:
                x, ac = attn_prefill(params["shared_attn"], x, S)
                acaches.append(ac)
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *mcaches),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *acaches),
        }
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    last = x[:, -1]
    logits = jnp.einsum("BE,EV->BV", last, _lm_head_weight(cfg, params),
                        preferred_element_type=jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                pos: jax.Array, cache: Params,
                ctx: MeshContext) -> Tuple[jax.Array, Params]:
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 (cache fill)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.constrain(x, ("batch", None, "embed"))
    fam = cfg.family

    def attn_decode(p, xx, cache_l):
        h = L.rms_norm(xx, p["ln1"], cfg.rms_eps)
        out, new_kv = L.mha_decode(p["attn"], h, cache_l, cfg, ctx, pos=pos)
        xx = xx + out
        h = L.rms_norm(xx, p["ln2"], cfg.rms_eps)
        if "mlp" in p:
            xx = xx + _apply_mlp(p["mlp"], h, cfg, ctx)
        else:
            mo, _ = MOE.moe_ffn(p["moe"], h, cfg, ctx)
            xx = xx + mo
        return xx, new_kv

    if fam in ("dense", "vlm", "audio", "moe"):
        def body(carry, xs):
            p_l, cache_l = xs
            xx, new_kv = attn_decode(p_l, carry, cache_l)
            return xx, new_kv
        x, new_attn = jax.lax.scan(body, x, (params["layers"], cache["attn"]))
        new_cache = {"attn": new_attn}
    elif fam == "ssm":
        def body(carry, xs):
            p_l, (mc, sc) = xs
            xx = carry
            h = L.rms_norm(xx, p_l["ln_m"], cfg.rms_eps)
            y, mc2 = XL.mlstm_decode(p_l["mlstm"], h, mc, cfg, ctx)
            xx = xx + y
            h = L.rms_norm(xx, p_l["ln_s"], cfg.rms_eps)
            y, sc2 = XL.slstm_decode(p_l["slstm"], h, sc, cfg, ctx)
            return xx + y, (mc2, sc2)
        x, (nm, ns) = jax.lax.scan(body, x,
                                   (params["layers"],
                                    (cache["mlstm"], cache["slstm"])))
        new_cache = {"mlstm": nm, "slstm": ns}
    elif fam == "hybrid":
        new_m, new_a = [], []
        ai = 0
        for start, size, has_attn in _hybrid_groups(cfg):
            sub = jax.tree.map(lambda a: a[start:start + size],
                               params["layers"])
            subc = jax.tree.map(lambda a: a[start:start + size],
                                cache["mamba"])

            def mbody(carry, xs):
                p_l, c_l = xs
                xx = carry
                h = L.rms_norm(xx, p_l["ln"], cfg.rms_eps)
                y, c2 = M2.mamba2_decode(p_l["mamba"], h, c_l, cfg, ctx)
                return xx + y, c2
            x, nc = jax.lax.scan(mbody, x, (sub, subc))
            new_m.append(nc)
            if has_attn:
                ac = jax.tree.map(lambda a: a[ai], cache["attn"])
                x, nac = attn_decode(params["shared_attn"], x, ac)
                new_a.append(nac)
                ai += 1
        new_cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_m),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_a),
        }
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("BSE,EV->BSV", x, _lm_head_weight(cfg, params),
                        preferred_element_type=jnp.float32)
    return logits[:, -1], new_cache
