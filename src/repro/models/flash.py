"""Memory-linear causal attention with a flash-style custom VJP.

Plain ``lax.scan`` online-softmax attention is memory-linear in the
*forward* pass but catastrophic under autodiff: scan residuals stash the
(nq, nkv, Bq, Bkv) score blocks for the backward pass (observed: 8.6 GB for
llama3.2-1b train_4k per device — EXPERIMENTS.md §Perf iteration 1).  The
fix is the standard FlashAttention recipe: save only (out, lse) and
recompute score blocks in the backward pass.

This file is the pure-jnp/lax implementation used by the model zoo (and the
oracle for the Pallas kernel in repro/kernels/flash_attention).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30
_f32 = jnp.float32


def _blocks(x, n, size):
    B, S, H, D = x.shape
    return x.reshape(B, n, size, H, D).swapaxes(0, 1)  # (n,B,sz,H,D)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024):
    """q,k,v: (B, S, H, D) with kv already expanded to H heads."""
    out, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk):
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0
    nq, nkv = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / np.sqrt(D)

    qb = _blocks(q, nq, q_chunk)
    kb = _blocks(k, nkv, kv_chunk)
    vb = _blocks(v, nkv, kv_chunk)
    kv_pos = jnp.arange(Skv).reshape(nkv, kv_chunk)

    def q_block(_, qi):
        qq, iq = qi
        q_pos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, kvj):
            m, l, acc = carry
            kk, vv, pos = kvj
            s = jnp.einsum("BqHD,BkHD->BHqk", qq, kk,
                           preferred_element_type=_f32) * scale
            if causal:
                mask = pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "BHqk,BkHD->BHqD", p.astype(vv.dtype), vv,
                preferred_element_type=_f32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, H, q_chunk), NEG_INF, _f32),
                jnp.zeros((B, H, q_chunk), _f32),
                jnp.zeros((B, H, q_chunk, D), _f32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, (kb, vb, kv_pos))
        l = jnp.maximum(l, 1e-30)
        o = (acc / l[..., None]).swapaxes(1, 2)            # (B,q,H,D)
        lse = (m + jnp.log(l)).swapaxes(1, 2)              # (B,q,H)
        return None, (o, lse)

    _, (ob, lseb) = jax.lax.scan(q_block, None, (qb, jnp.arange(nq)))
    out = ob.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    lse = lseb.swapaxes(0, 1).reshape(B, Sq, H)
    return out, lse


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nkv = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / np.sqrt(D)

    delta = jnp.sum(do.astype(_f32) * out.astype(_f32), axis=-1)  # (B,S,H)

    qb = _blocks(q, nq, q_chunk)
    kb = _blocks(k, nkv, kv_chunk)
    vb = _blocks(v, nkv, kv_chunk)
    dob = _blocks(do, nq, q_chunk)
    lseb = lse.reshape(B, nq, q_chunk, H).swapaxes(0, 1)
    deltab = delta.reshape(B, nq, q_chunk, H).swapaxes(0, 1)
    q_pos = jnp.arange(Sq).reshape(nq, q_chunk)
    kv_pos = jnp.arange(Skv).reshape(nkv, kv_chunk)

    def kv_block(dq_acc, kvj):
        kk, vv, pos_k, jk = kvj

        def q_block(carry, qi):
            dk, dv = carry
            qq, doo, lse_i, delta_i, pos_q = qi
            s = jnp.einsum("BqHD,BkHD->BHqk", qq, kk,
                           preferred_element_type=_f32) * scale
            if causal:
                mask = pos_k[None, :] <= pos_q[:, None]
                s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i.swapaxes(1, 2)[..., None])     # (B,H,q,k)
            dv_new = dv + jnp.einsum("BHqk,BqHD->BkHD", p,
                                     doo.astype(_f32),
                                     preferred_element_type=_f32)
            dp = jnp.einsum("BqHD,BkHD->BHqk", doo.astype(_f32),
                            vv.astype(_f32), preferred_element_type=_f32)
            ds = p * (dp - delta_i.swapaxes(1, 2)[..., None]) * scale
            dk_new = dk + jnp.einsum("BHqk,BqHD->BkHD", ds,
                                     qq.astype(_f32),
                                     preferred_element_type=_f32)
            dq_i = jnp.einsum("BHqk,BkHD->BqHD", ds, kk.astype(_f32),
                              preferred_element_type=_f32)
            return (dk_new, dv_new), dq_i

        init = (jnp.zeros((B, kv_chunk, H, D), _f32),
                jnp.zeros((B, kv_chunk, H, D), _f32))
        (dk_j, dv_j), dq_blocks = jax.lax.scan(
            q_block, init, (qb, dob, lseb, deltab, q_pos))
        dq_acc = dq_acc + dq_blocks                        # (nq,B,qc,H,D)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, q_chunk, H, D), _f32)
    dq_acc, (dkb, dvb) = jax.lax.scan(kv_block, dq0,
                                      (kb, vb, kv_pos, jnp.arange(nkv)))
    dq = dq_acc.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    dk = dkb.swapaxes(0, 1).reshape(B, Skv, H, D).astype(k.dtype)
    dv = dvb.swapaxes(0, 1).reshape(B, Skv, H, D).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
