"""Chunked gated linear attention — the shared recurrence engine.

Both Mamba2's SSD and xLSTM's mLSTM are instances of the same per-head
recurrence over matrix state ``S`` (and optional normalizer ``n``)::

    S_t = exp(log_f_t) * S_{t-1} + i_t * k_t v_t^T        S: (Dk, Dv)
    n_t = exp(log_f_t) * n_{t-1} + i_t * k_t              n: (Dk,)
    y_t = q_t^T S_t            [ / max(|q_t^T n_t|, 1)    if normalized ]

The chunkwise-parallel form (chunk length Q) computes an intra-chunk
"attention" term with a decay mask plus an inter-chunk state carry, giving
O(S·Q) work and O(S) memory — this is what makes the 500k-token shapes
feasible and is the sub-quadratic path referenced in DESIGN.md §4.
All state math in fp32 (the TPU analogue of the paper's "keep the working
set inside the trusted fast memory": state lives in registers/VMEM).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_BIG = -1e30
_f32 = jnp.float32


def chunked_gla(
    q: jax.Array,        # (B, S, H, Dk)
    k: jax.Array,        # (B, S, H, Dk)
    v: jax.Array,        # (B, S, H, Dv)
    log_f: jax.Array,    # (B, S, H)   per-step log decay (<= 0)
    i_gate: jax.Array,   # (B, S, H)   input gate (>= 0)
    *,
    chunk: int = 256,
    normalize: bool = False,
    init_state: Optional[Tuple[jax.Array, jax.Array]] = None,
    return_state: bool = False,
):
    """Returns y: (B, S, H, Dv) [and final (S, n) state if requested]."""
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    qc = q.reshape(B, nc, chunk, H, Dk).swapaxes(0, 1)
    kc = k.reshape(B, nc, chunk, H, Dk).swapaxes(0, 1)
    vc = v.reshape(B, nc, chunk, H, Dv).swapaxes(0, 1)
    fc = log_f.reshape(B, nc, chunk, H).swapaxes(0, 1).astype(_f32)
    ic = i_gate.reshape(B, nc, chunk, H).swapaxes(0, 1).astype(_f32)

    if init_state is None:
        S0 = jnp.zeros((B, H, Dk, Dv), _f32)
        n0 = jnp.zeros((B, H, Dk), _f32)
    else:
        S0, n0 = init_state

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def block(carry, xs):
        Sp, np_ = carry                          # (B,H,Dk,Dv), (B,H,Dk)
        qb, kb, vb, fb, ib = xs                  # (B,Q,H,*)
        cum = jnp.cumsum(fb, axis=1)             # (B,Q,H), non-increasing
        tot = cum[:, -1, :]                      # (B,H)
        qf, kf, vf = (t.astype(_f32) for t in (qb, kb, vb))

        # intra-chunk: A[t,s] = exp(cum_t - cum_s) * i_s * (q_t . k_s), s<=t
        scores = jnp.einsum("BtHD,BsHD->BHts", qf, kf)
        decay = cum[:, :, None, :] - cum[:, None, :, :]        # (B,t,s,H)
        decay = jnp.where(tri[None, :, :, None], decay, NEG_BIG)
        gate = jnp.exp(decay) * ib[:, None, :, :]              # (B,t,s,H)
        gate = gate.transpose(0, 3, 1, 2)                      # (B,H,t,s)
        A = scores * gate
        y = jnp.einsum("BHts,BsHD->BtHD", A, vf)

        # inter-chunk: contribution of the carried state
        qdec = qf * jnp.exp(cum)[..., None]                    # (B,Q,H,Dk)
        y = y + jnp.einsum("BtHK,BHKV->BtHV", qdec, Sp)

        if normalize:
            nk = jnp.einsum("BHts,BsHK->BtHK", gate, kf)
            n_t = nk + jnp.einsum("BtH,BHK->BtHK", jnp.exp(cum), np_)
            denom = jnp.abs(jnp.einsum("BtHK,BtHK->BtH", qf, n_t))
            y = y / jnp.maximum(denom, 1.0)[..., None]

        # state carry to the next chunk
        kscale = (jnp.exp(tot[:, None, :] - cum) * ib)[..., None]  # (B,Q,H,1)
        ks = kf * kscale
        S_new = jnp.exp(tot)[:, :, None, None] * Sp + jnp.einsum(
            "BsHK,BsHV->BHKV", ks, vf)
        n_new = jnp.exp(tot)[..., None] * np_ + jnp.einsum("BsHK->BHK", ks)
        return (S_new, n_new), y

    (Sf, nf), ys = jax.lax.scan(block, (S0, n0), (qc, kc, vc, fc, ic))
    y = ys.swapaxes(0, 1).reshape(B, S, H, Dv)
    if return_state:
        return y, (Sf, nf)
    return y


def gla_decode_step(
    q: jax.Array,        # (B, H, Dk)
    k: jax.Array,
    v: jax.Array,        # (B, H, Dv)
    log_f: jax.Array,    # (B, H)
    i_gate: jax.Array,   # (B, H)
    state: Tuple[jax.Array, jax.Array],   # S: (B,H,Dk,Dv), n: (B,H,Dk)
    *,
    normalize: bool = False,
):
    """Single-token recurrent update; O(1) per token."""
    Sp, np_ = state
    f = jnp.exp(log_f.astype(_f32))[..., None]                 # (B,H,1)
    i = i_gate.astype(_f32)[..., None]                         # (B,H,1)
    qf, kf, vf = (t.astype(_f32) for t in (q, k, v))
    S_new = f[..., None] * Sp + (i * kf)[..., None] * vf[..., None, :]
    n_new = f * np_ + i * kf
    y = jnp.einsum("BHK,BHKV->BHV", qf, S_new)
    if normalize:
        denom = jnp.abs(jnp.einsum("BHK,BHK->BH", qf, n_new))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y, (S_new, n_new)


def gla_reference(q, k, v, log_f, i_gate, *, normalize=False):
    """Pure per-step oracle (sequential scan) for testing chunked_gla."""
    B, S, H, Dk = q.shape

    def step(state, xs):
        qs, ks, vs, fs, is_ = xs
        y, state = gla_decode_step(qs, ks, vs, fs, is_, state,
                                   normalize=normalize)
        return state, y

    S0 = jnp.zeros((B, H, Dk, v.shape[-1]), _f32)
    n0 = jnp.zeros((B, H, Dk), _f32)
    xs = tuple(x.swapaxes(0, 1) for x in (q, k, v, log_f, i_gate))
    _, ys = jax.lax.scan(step, (S0, n0), xs)
    return ys.swapaxes(0, 1)
