"""Hierarchical causal attention: recursive halving to eliminate the
masked-FLOP waste of scan-based causal attention.

A causal attention over S positions decomposes as::

    [ A  0 ]   A = causal attention over the first half
    [ B  C ]   C = causal attention over the second half
               B = *dense* (unmasked) attention of the second-half queries
                   over the first-half keys — no wasted lanes.

Recursing log2(S/base) times, every FLOP except the tiny base-case
diagonal blocks is dense: HLO compute drops from S^2 to ~S^2/2 (the true
causal cost), with **static shapes at every level** — something the
lax.scan-over-kv-chunks formulation cannot do (it must visit every chunk
and mask).  Each dense rectangle runs through the flash forward (online
softmax, memory-linear) and partial results merge by log-sum-exp.

Used for inference paths (prefill); training keeps the custom-VJP flash.
See EXPERIMENTS.md §Perf for the measured FLOP reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.flash import _flash_fwd_impl

_f32 = jnp.float32


def _merge(o1, lse1, o2, lse2):
    """Merge two partial attention results over the same queries.

    o_i: (B,S,H,D) normalized partial outputs; lse_i: (B,S,H) log-sum-exp.
    """
    m = jnp.maximum(lse1, lse2)
    w1 = jnp.exp(lse1 - m)[..., None]
    w2 = jnp.exp(lse2 - m)[..., None]
    o = (o1.astype(_f32) * w1 + o2.astype(_f32) * w2) / (w1 + w2)
    lse = m + jnp.log(jnp.exp(lse1 - m) + jnp.exp(lse2 - m))
    return o.astype(o1.dtype), lse


def hier_causal_attention(q, k, v, *, base: int = 1024, q_chunk: int = 512,
                          kv_chunk: int = 1024):
    """q,k,v: (B,S,H,D), kv expanded to H heads. Returns (B,S,H,D)."""
    out, _ = _rec(q, k, v, base, q_chunk, kv_chunk)
    return out


def _rec(q, k, v, base, q_chunk, kv_chunk):
    S = q.shape[1]
    if S <= base:
        return _flash_fwd_impl(q, k, v, True, min(q_chunk, S),
                               min(kv_chunk, S))
    half = S // 2
    o1, lse1 = _rec(q[:, :half], k[:, :half], v[:, :half], base, q_chunk,
                    kv_chunk)
    o2, lse2 = _rec(q[:, half:], k[:, half:], v[:, half:], base, q_chunk,
                    kv_chunk)
    # dense rectangle: second-half queries attend ALL first-half keys
    oc, lsec = _flash_fwd_impl(q[:, half:], k[:, :half], v[:, :half], False,
                               min(q_chunk, half), min(kv_chunk, half))
    o2m, _ = _merge(o2, lse2, oc, lsec)
    out = jnp.concatenate([o1, o2m], axis=1)
    lse = jnp.concatenate(
        [lse1, jnp.logaddexp(lse2, lsec)], axis=1)
    return out, lse
