"""Shared neural-net components: param templates, norms, RoPE, attention.

Conventions
-----------
* Params are nested dicts of arrays; their *templates* are nested dicts of
  :class:`ParamSpec` carrying shape + logical axis names.  The template is
  the single source of truth: real init, abstract (dry-run) params, and
  shardings all derive from it.
* Activations are bf16; softmax / norms / recurrent states accumulate fp32.
* einsum letters: B batch, S/T seq, H q-heads, K kv-heads, D head_dim,
  E d_model, F d_ff, X experts, C capacity, V vocab, N state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.meshctx import MeshContext

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------
# Param templates
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # stddev override; default 1/sqrt(fan_in)
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))

    def initialize(self, key: jax.Array) -> jax.Array:
        dt = jnp.dtype(self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dt)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], template: Params) -> Params:
    return jax.tree.map(fn, template, is_leaf=is_spec)


def abstract_from_template(template: Params) -> Params:
    return tree_map_specs(lambda s: s.abstract(), template)


def init_from_template(template: Params, key: jax.Array) -> Params:
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [spec.initialize(k) for spec, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def shardings_from_template(template: Params, ctx: MeshContext) -> Params:
    return tree_map_specs(lambda s: ctx.sharding(s.logical, s.shape), template)


def stacked(spec: ParamSpec, n: int, axis_name: Optional[str] = "layers") -> ParamSpec:
    """Prepend a scan (layers) dimension to a spec."""
    return dataclasses.replace(
        spec, shape=(n, *spec.shape), logical=(axis_name, *spec.logical)
    )


def stack_template(template: Params, n: int) -> Params:
    return tree_map_specs(lambda s: stacked(s, n), template)


# ---------------------------------------------------------------------------
# Basic ops
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, ctx: MeshContext) -> jax.Array:
    g = jnp.einsum("...E,EF->...F", x, w_gate)
    u = jnp.einsum("...E,EF->...F", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = ctx.constrain(h, ("batch", "seq", "mlp")) if h.ndim == 3 else h
    return jnp.einsum("...F,FE->...E", h, w_down)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (D/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked online-softmax => memory-linear in seq)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention_template(cfg, prefix_dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, h, k, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, h * dh), ("embed", "heads")),
        "wk": ParamSpec((d, k * dh), ("embed", "kv_heads")),
        "wv": ParamSpec((d, k * dh), ("embed", "kv_heads")),
        "wo": ParamSpec((h * dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((h * dh,), ("heads",), init="zeros")
        t["bk"] = ParamSpec((k * dh,), ("kv_heads",), init="zeros")
        t["bv"] = ParamSpec((k * dh,), ("kv_heads",), init="zeros")
    return t


def _project_qkv(p: Params, x: jax.Array, cfg, ctx: MeshContext,
                 positions: jax.Array):
    B, S, _ = x.shape
    h, k, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("BSE,EH->BSH", x, p["wq"])
    kk = jnp.einsum("BSE,EK->BSK", x, p["wk"])
    v = jnp.einsum("BSE,EK->BSK", x, p["wv"])
    if cfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, dh)
    kk = kk.reshape(B, S, k, dh)
    v = v.reshape(B, S, k, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    kk = apply_rope(kk, positions, cfg.rope_theta)
    q = ctx.constrain(q, ("batch", "seq", "heads", None))
    return q, kk, v


def repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """(B,S,K,D) -> (B,S,K*groups,D) by repeating each kv head `groups` times."""
    if groups == 1:
        return x
    B, S, K, D = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, K, groups, D)).reshape(
        B, S, K * groups, D)


def chunked_attention(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Skv, H, D)  (kv already repeated to H heads)
    v: jax.Array,          # (B, Skv, H, D)
    *,
    causal: bool,
    q_offset: Any = 0,     # absolute position of q[0] (int or traced scalar)
    kv_valid: Optional[Any] = None,  # #valid kv positions (decode w/ cache)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, memory O(S) instead of O(S^2).

    Both loops are `lax.scan`s so the HLO stays compact under scan-over-layers.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = max(Sq // q_chunk, 1)
    nkv = max(Skv // kv_chunk, 1)
    # Fall back to unchunked remainder handling: require divisibility.
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)

    scale = 1.0 / np.sqrt(D)
    q = q.reshape(B, nq, q_chunk, H, D).swapaxes(0, 1)    # (nq,B,qc,H,D)
    kr = k.reshape(B, nkv, kv_chunk, H, D).swapaxes(0, 1)  # (nkv,B,kc,H,D)
    vr = v.reshape(B, nkv, kv_chunk, H, D).swapaxes(0, 1)

    kv_pos = (jnp.arange(nkv * kv_chunk).reshape(nkv, kv_chunk))

    def q_block(_, qi):
        qb, iq = qi                                        # (B,qc,H,D), idx
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, kvj):
            m, l, acc = carry
            kb, vb, pos = kvj                              # (B,kc,H,D), (kc,)
            s = jnp.einsum("BqHD,BkHD->BHqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = mask & (pos[None, :] <= q_pos[:, None])
            if kv_valid is not None:
                mask = mask & (pos[None, :] < kv_valid)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))         # (B,H,q)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "BHqk,BkHD->BHqD", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, H, q_chunk), jnp.float32),
            jnp.zeros((B, H, q_chunk, D), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_block, init, (kr, vr, kv_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,H,q,D)
        return None, out.swapaxes(1, 2)                    # (B,q,H,D)

    _, outs = jax.lax.scan(q_block, None, (q, jnp.arange(nq)))  # (nq,B,qc,H,D)
    out = outs.swapaxes(0, 1).reshape(B, Sq, H, D)
    return out


def mha(p: Params, x: jax.Array, cfg, ctx: MeshContext, *,
        positions: jax.Array, q_chunk: int = 512, kv_chunk: int = 1024,
        attn_impl: str = "flash", return_kv: bool = False):
    """Full (training / prefill) causal self-attention."""
    B, S, _ = x.shape
    groups = cfg.num_heads // cfg.num_kv_heads
    q, k, v = _project_qkv(p, x, cfg, ctx, positions)
    kv = (k, v)
    k = repeat_kv(k, groups)
    v = repeat_kv(v, groups)
    k = ctx.constrain(k, ("batch", "seq", "heads", None))
    v = ctx.constrain(v, ("batch", "seq", "heads", None))
    if attn_impl == "pallas_flash":
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=True)
    elif attn_impl == "chunked":  # scan-autodiff reference (memory-hungry bwd)
        out = chunked_attention(q, k, v, causal=True,
                                q_chunk=q_chunk, kv_chunk=kv_chunk)
    elif attn_impl == "hier":  # inference: recursive-halving causal (~S^2/2)
        from repro.models.hier_attn import hier_causal_attention
        out = hier_causal_attention(q, k, v, q_chunk=q_chunk,
                                    kv_chunk=kv_chunk)
    else:  # "flash": custom-VJP online-softmax (default)
        from repro.models.flash import flash_attention
        out = flash_attention(q, k, v, True, q_chunk, kv_chunk)
    out = ctx.constrain(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("BSX,XE->BSE", out.reshape(B, S, -1).astype(x.dtype),
                   p["wo"])
    if return_kv:
        return y, kv
    return y


def mha_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array], cfg,
               ctx: MeshContext, *, pos: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode with a KV cache (grouped einsum — KV is *not*
    repeated to H heads, so cache reads stay at the GQA byte count).

    cache: {"k": (B, Smax, K, D), "v": (B, Smax, K, D)}; `pos` (scalar) is the
    index of the new token (== number of valid cache entries before update).
    """
    B, S1, _ = x.shape
    assert S1 == 1
    K, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    Dh = cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, ctx, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                      (0, pos, 0, 0))
    Smax = ck.shape[1]
    qg = q.reshape(B, K, G, Dh)                       # (B,K,G,D) single token
    s = jnp.einsum("BKGD,BSKD->BKGS", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / np.sqrt(Dh)
    valid = jnp.arange(Smax)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("BKGS,BSKD->BKGD", w.astype(cv.dtype), cv)
    y = jnp.einsum("BSX,XE->BSE",
                   out.reshape(B, 1, K * G * Dh).astype(x.dtype), p["wo"])
    return y, {"k": ck, "v": cv}


def attention_cache_template(cfg, batch: int, max_seq: int,
                             dtype: str = "bfloat16") -> Dict[str, ParamSpec]:
    k, dh = cfg.num_kv_heads, cfg.head_dim
    spec = ParamSpec((batch, max_seq, k, dh),
                     ("batch", "kv_seq", "kv_heads", None),
                     init="zeros", dtype=dtype)
    return {"k": spec, "v": spec}
