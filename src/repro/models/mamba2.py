"""Mamba2 (SSD) block, built on the shared chunked-GLA engine.

The SSD recurrence ``h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T`` with scalar
per-head ``A`` maps onto :func:`repro.models.gla.chunked_gla` with
``q=C, k=B, v=x, log_f = -exp(A_log)·dt, i = dt`` (see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.meshctx import MeshContext
from repro.models.gla import chunked_gla, gla_decode_step
from repro.models.layers import ParamSpec, Params, rms_norm


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = d_inner // ssm.headdim
    return d_inner, nheads, ssm.state_dim, ssm.conv_width


def mamba2_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_inner, nheads, N, W = _dims(cfg)
    return {
        "in_proj_z": ParamSpec((d, d_inner), ("embed", "mlp")),
        "in_proj_x": ParamSpec((d, d_inner), ("embed", "mlp")),
        "in_proj_B": ParamSpec((d, N), ("embed", None)),
        "in_proj_C": ParamSpec((d, N), ("embed", None)),
        "in_proj_dt": ParamSpec((d, nheads), ("embed", "heads")),
        "conv_x": ParamSpec((W, d_inner), (None, "mlp"), init="normal", scale=0.5),
        "conv_B": ParamSpec((W, N), (None, None), init="normal", scale=0.5),
        "conv_C": ParamSpec((W, N), (None, None), init="normal", scale=0.5),
        "A_log": ParamSpec((nheads,), ("heads",), init="zeros", dtype="float32"),
        "D": ParamSpec((nheads,), ("heads",), init="ones", dtype="float32"),
        "dt_bias": ParamSpec((nheads,), ("heads",), init="zeros", dtype="float32"),
        "norm_inner": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv as a sum of shifts. x: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(W):
        out = out + pad[:, j:j + S].astype(jnp.float32) * w[j].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def _conv_step(state: jax.Array, xt: jax.Array, w: jax.Array):
    """state: (B, W-1, C) previous inputs; xt: (B, C). Returns (out, state)."""
    W = w.shape[0]
    window = jnp.concatenate([state, xt[:, None]], axis=1)      # (B,W,C)
    out = jnp.einsum("BWC,WC->BC", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out).astype(xt.dtype), window[:, 1:]


def _gates(p: Params, dt_pre: jax.Array):
    """dt_pre: (..., H) -> (log_f, i) both (..., H) fp32."""
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                    # (H,) < 0
    return dt * A, dt


def _mamba2_core(p: Params, x: jax.Array, cfg: ModelConfig, ctx: MeshContext,
                 *, chunk: int = 0, with_state: bool = False):
    B, S, _ = x.shape
    d_inner, nheads, N, W = _dims(cfg)
    hd = cfg.ssm.headdim
    chunk = chunk or cfg.ssm.chunk_size

    z = jnp.einsum("BSE,EI->BSI", x, p["in_proj_z"])
    pre_x = jnp.einsum("BSE,EI->BSI", x, p["in_proj_x"])
    pre_B = jnp.einsum("BSE,EN->BSN", x, p["in_proj_B"])
    pre_C = jnp.einsum("BSE,EN->BSN", x, p["in_proj_C"])
    xs = _causal_conv(pre_x, p["conv_x"])
    Bm = _causal_conv(pre_B, p["conv_B"])
    Cm = _causal_conv(pre_C, p["conv_C"])
    log_f, i_gate = _gates(p, jnp.einsum("BSE,EH->BSH", x, p["in_proj_dt"]))

    v = xs.reshape(B, S, nheads, hd)
    v = ctx.constrain(v, ("batch", "seq", "heads", None))
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, nheads, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, nheads, N))
    res = chunked_gla(q, k, v, log_f, i_gate, chunk=min(chunk, S),
                      return_state=with_state)
    y, state = res if with_state else (res, None)
    y = y + p["D"][None, None, :, None] * v.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_inner"], cfg.rms_eps)
    out = jnp.einsum("BSI,IE->BSE", y, p["out_proj"])
    if not with_state:
        return out
    cache = {
        "conv_x": pre_x[:, S - (W - 1):],
        "conv_B": pre_B[:, S - (W - 1):],
        "conv_C": pre_C[:, S - (W - 1):],
        "ssm": state[0],
        "ssm_n": state[1],
    }
    return out, cache


def mamba2_forward(p: Params, x: jax.Array, cfg: ModelConfig, ctx: MeshContext,
                   *, chunk: int = 0) -> jax.Array:
    """x: (B, S, E) -> (B, S, E)."""
    return _mamba2_core(p, x, cfg, ctx, chunk=chunk, with_state=False)


def mamba2_forward_with_state(p: Params, x: jax.Array, cfg: ModelConfig,
                              ctx: MeshContext, *, chunk: int = 0):
    """Prefill variant: also returns the decode cache."""
    return _mamba2_core(p, x, cfg, ctx, chunk=chunk, with_state=True)


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1)/token)
# ---------------------------------------------------------------------------


def mamba2_cache_template(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    d_inner, nheads, N, W = _dims(cfg)
    return {
        "conv_x": ParamSpec((batch, W - 1, d_inner), ("batch", None, "mlp"),
                            init="zeros"),
        "conv_B": ParamSpec((batch, W - 1, N), ("batch", None, None),
                            init="zeros"),
        "conv_C": ParamSpec((batch, W - 1, N), ("batch", None, None),
                            init="zeros"),
        "ssm": ParamSpec((batch, nheads, N, cfg.ssm.headdim),
                         ("batch", "heads", None, None), init="zeros",
                         dtype="float32"),
        "ssm_n": ParamSpec((batch, nheads, N), ("batch", "heads", None),
                           init="zeros", dtype="float32"),
    }


def mamba2_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                  cfg: ModelConfig, ctx: MeshContext):
    """x: (B, 1, E). Returns (y, new_cache)."""
    B = x.shape[0]
    d_inner, nheads, N, W = _dims(cfg)
    hd = cfg.ssm.headdim
    xt = x[:, 0]

    z = jnp.einsum("BE,EI->BI", xt, p["in_proj_z"])
    xc, conv_x = _conv_step(cache["conv_x"],
                            jnp.einsum("BE,EI->BI", xt, p["in_proj_x"]),
                            p["conv_x"])
    Bc, conv_B = _conv_step(cache["conv_B"],
                            jnp.einsum("BE,EN->BN", xt, p["in_proj_B"]),
                            p["conv_B"])
    Cc, conv_C = _conv_step(cache["conv_C"],
                            jnp.einsum("BE,EN->BN", xt, p["in_proj_C"]),
                            p["conv_C"])
    log_f, i_gate = _gates(p, jnp.einsum("BE,EH->BH", xt, p["in_proj_dt"]))

    v = xc.reshape(B, nheads, hd)
    q = jnp.broadcast_to(Cc[:, None, :], (B, nheads, N))
    k = jnp.broadcast_to(Bc[:, None, :], (B, nheads, N))
    y, (S_new, n_new) = gla_decode_step(q, k, v, log_f, i_gate,
                                        (cache["ssm"], cache["ssm_n"]))
    y = y + p["D"][None, :, None] * v.astype(jnp.float32)
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_inner"], cfg.rms_eps)
    out = jnp.einsum("BI,IE->BE", y, p["out_proj"])[:, None]
    return out, {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "ssm": S_new, "ssm_n": n_new}
