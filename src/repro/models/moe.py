"""Expert-parallel Mixture-of-Experts FFN.

Design (see DESIGN.md §5): experts are sharded over the ``model`` mesh axis;
activations stay replicated across model ranks within each data shard.  Each
expert shard selects the tokens routed to *its* experts (fixed capacity,
sort-based dispatch — no (T, X, C) one-hot dispatch tensor, which would be
O(terabytes) at kimi-k2 scale), applies its experts' SwiGLU, and the top-k
combine is a single ``psum`` over ``model`` — the same collective cost as a
Megatron TP FFN all-reduce, with a GSPMD-predictable schedule.

Implemented with ``shard_map`` so the dispatch is *local by construction*;
GSPMD cannot accidentally all-gather the token stream.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compat import shard_map

from repro.configs.base import ModelConfig
from repro.dist.meshctx import MeshContext
from repro.models.layers import ParamSpec, Params


def moe_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    d, X, F = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    # "moe_ff" maps to ("data",) for FSDP/ZeRO-3-style expert-weight storage
    # (kimi-k2 1T: expert weights per chip drop 129 GB -> 8 GB; a per-layer
    # all-gather re-materializes them transiently inside the layer scan).
    # Default rule is () => fully resident.
    return {
        "router": ParamSpec((d, X), ("embed", None), dtype="float32"),
        "wg": ParamSpec((X, d, F), ("experts", "embed", "moe_ff")),
        "wu": ParamSpec((X, d, F), ("experts", "embed", "moe_ff")),
        "wd": ParamSpec((X, F, d), ("experts", "moe_ff", "embed")),
    }


def _capacity(tokens: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(np.ceil(tokens * top_k / num_experts * cf))
    return max(8, int(np.ceil(c / 8)) * 8)


def _moe_block(x, router, wg, wu, wd, *, cfg: ModelConfig, mp: int,
               all_axes: Tuple[str, ...]):
    """Per-(data, model)-shard body. x: (Bl, S, E) replicated over model."""
    moe = cfg.moe
    Bl, S, E = x.shape
    T = Bl * S
    X, k = moe.num_experts, moe.top_k
    E_local = X // mp
    C = _capacity(T, k, X, moe.capacity_factor)
    my_rank = jax.lax.axis_index("model")
    lo = my_rank * E_local

    xf = x.reshape(T, E)
    logits = jnp.einsum("TE,EX->TX", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                     # (T,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style), pmean over the mesh
    me = probs.mean(axis=0)                                  # (X,)
    ce = jnp.zeros((X,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (T * k))
    aux = X * jnp.sum(me * ce)
    aux = jax.lax.pmean(aux, all_axes)

    # ---- dispatch: flat assignments, keep only this shard's experts
    a_eid = topi.reshape(-1)                                 # (T*k,)
    a_tok = jnp.repeat(jnp.arange(T), k)
    a_w = topw.reshape(-1)
    mine = (a_eid >= lo) & (a_eid < lo + E_local)
    local_eid = jnp.where(mine, a_eid - lo, E_local)         # E_local = "other"
    order = jnp.argsort(local_eid)                           # stable, groups experts
    s_eid = local_eid[order]
    s_tok = a_tok[order]
    s_w = a_w[order]
    counts = jax.ops.segment_sum(jnp.ones_like(s_eid), s_eid,
                                 num_segments=E_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(T * k) - starts[s_eid]
    keep = (s_eid < E_local) & (slot < C)
    dest = jnp.where(keep, s_eid * C + slot, E_local * C)    # last row = trash

    # .add (not .set): dest is unique for kept rows; the trash row accumulates
    # dropped tokens but is sliced off, so their gradient contribution is 0.
    xbuf = jnp.zeros((E_local * C + 1, E), x.dtype).at[dest].add(xf[s_tok])
    xe = xbuf[:-1].reshape(E_local, C, E)

    # ---- expert SwiGLU (batched over local experts)
    g = jnp.einsum("XCE,XEF->XCF", xe, wg)
    u = jnp.einsum("XCE,XEF->XCF", xe, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    oe = jnp.einsum("XCF,XFE->XCE", h, wd).reshape(E_local * C, E)

    # ---- combine: gather each assignment's expert output, weight, sum per tok
    contrib = oe[jnp.minimum(dest, E_local * C - 1)]
    contrib = jnp.where(keep[:, None], contrib, 0)
    contrib = contrib.astype(jnp.float32) * s_w[:, None]
    y = jax.ops.segment_sum(contrib, s_tok, num_segments=T)  # (T,E) fp32
    # combine across expert shards in bf16: halves the per-layer all-reduce
    # payload (EXPERIMENTS.md §Perf kimi iteration 2); local accumulation
    # stays fp32, only the wire format narrows.
    y = jax.lax.psum(y.astype(jnp.bfloat16), "model")
    return y.reshape(Bl, S, E).astype(x.dtype), aux


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
            ctx: MeshContext) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, E) batch-sharded. Returns (out, aux_loss)."""
    mesh = ctx.mesh
    mp = ctx.axis_size("model")
    dp = ctx.dp_axes
    # FSDP gather: if expert weights are stored sharded over data ("moe_ff"),
    # re-materialize full (per-model-shard) weights just for this layer.
    wg_f = ctx.constrain(p["wg"], ("experts", None, None))
    wu_f = ctx.constrain(p["wu"], ("experts", None, None))
    wd_f = ctx.constrain(p["wd"], ("experts", None, None))
    x_spec = P(dp if dp else None, None, None)
    w_spec = {
        "router": P(None, None),
        "wg": P("model", None, None),
        "wu": P("model", None, None),
        "wd": P("model", None, None),
    }
    all_axes = tuple(mesh.axis_names)
    fn = partial(_moe_block, cfg=cfg, mp=mp, all_axes=all_axes)
    out, aux = shard_map(
        fn,
        mesh=mesh,
        in_specs=(x_spec, w_spec["router"], w_spec["wg"], w_spec["wu"],
                  w_spec["wd"]),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], wg_f, wu_f, wd_f)
    return out, aux
