"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel via GLA) and
sLSTM (scalar memory with recurrent gate connections, inherently sequential).

Faithfulness notes (DESIGN.md §2):
* mLSTM uses the stabilized-exponential input gate replaced by a sigmoid
  (TPU-friendly; the normalizer ``n`` is kept, so outputs stay bounded).
* sLSTM keeps the *true* stabilized exponential gating and the recurrent
  (h_{t-1} -> gates) connections — it is sequential by construction, which
  is exactly what the xLSTM paper states; we lax.scan it.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.meshctx import MeshContext
from repro.models.gla import chunked_gla, gla_decode_step
from repro.models.layers import ParamSpec, Params, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    dp = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
    H = cfg.num_heads
    return dp, H, dp // H


def mlstm_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    dp, H, dh = _mlstm_dims(cfg)
    return {
        "up_x": ParamSpec((d, dp), ("embed", "mlp")),
        "up_z": ParamSpec((d, dp), ("embed", "mlp")),
        "wq": ParamSpec((dp, dp), ("mlp", "heads")),
        "wk": ParamSpec((dp, dp), ("mlp", "heads")),
        "wv": ParamSpec((dp, dp), ("mlp", "heads")),
        "w_i": ParamSpec((dp, H), ("mlp", "heads")),
        "w_f": ParamSpec((dp, H), ("mlp", "heads")),
        "b_f": ParamSpec((H,), ("heads",), init="ones", dtype="float32"),
        "norm_h": ParamSpec((dp,), ("mlp",), init="ones"),
        "down": ParamSpec((dp, d), ("mlp", "embed")),
    }


def _mlstm_qkvgates(p: Params, xin: jax.Array, H: int, dh: int):
    B = xin.shape[:-1]
    q = jnp.einsum("...I,IJ->...J", xin, p["wq"]).reshape(*B, H, dh) / (dh ** 0.5)
    k = jnp.einsum("...I,IJ->...J", xin, p["wk"]).reshape(*B, H, dh)
    v = jnp.einsum("...I,IJ->...J", xin, p["wv"]).reshape(*B, H, dh)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("...I,IH->...H", xin, p["w_f"]).astype(jnp.float32)
        + p["b_f"])
    i_gate = jax.nn.sigmoid(
        jnp.einsum("...I,IH->...H", xin, p["w_i"]).astype(jnp.float32))
    return q, k, v, log_f, i_gate


def _mlstm_core(p: Params, x: jax.Array, cfg: ModelConfig, ctx: MeshContext,
                *, with_state: bool = False):
    B, S, _ = x.shape
    dp, H, dh = _mlstm_dims(cfg)
    xin = jnp.einsum("BSE,EI->BSI", x, p["up_x"])
    z = jnp.einsum("BSE,EI->BSI", x, p["up_z"])
    q, k, v, log_f, i_gate = _mlstm_qkvgates(p, xin, H, dh)
    res = chunked_gla(q, k, v, log_f, i_gate,
                      chunk=min(cfg.xlstm.chunk_size, S), normalize=True,
                      return_state=with_state)
    y, state = res if with_state else (res, None)
    y = y.reshape(B, S, dp).astype(x.dtype)
    y = rms_norm(y, p["norm_h"], cfg.rms_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("BSI,IE->BSE", y, p["down"])
    if with_state:
        return out, {"S": state[0], "n": state[1]}
    return out


def mlstm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  ctx: MeshContext) -> jax.Array:
    return _mlstm_core(p, x, cfg, ctx, with_state=False)


def mlstm_forward_with_state(p: Params, x: jax.Array, cfg: ModelConfig,
                             ctx: MeshContext):
    return _mlstm_core(p, x, cfg, ctx, with_state=True)


def mlstm_cache_template(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    dp, H, dh = _mlstm_dims(cfg)
    return {
        "S": ParamSpec((batch, H, dh, dh), ("batch", "heads", None, None),
                       init="zeros", dtype="float32"),
        "n": ParamSpec((batch, H, dh), ("batch", "heads", None),
                       init="zeros", dtype="float32"),
    }


def mlstm_decode(p: Params, x: jax.Array, cache, cfg: ModelConfig,
                 ctx: MeshContext):
    B = x.shape[0]
    dp, H, dh = _mlstm_dims(cfg)
    xt = x[:, 0]
    xin = jnp.einsum("BE,EI->BI", xt, p["up_x"])
    z = jnp.einsum("BE,EI->BI", xt, p["up_z"])
    q, k, v, log_f, i_gate = _mlstm_qkvgates(p, xin, H, dh)
    y, (S_new, n_new) = gla_decode_step(q, k, v, log_f, i_gate,
                                        (cache["S"], cache["n"]),
                                        normalize=True)
    y = y.reshape(B, dp).astype(x.dtype)
    y = rms_norm(y, p["norm_h"], cfg.rms_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("BI,IE->BE", y, p["down"])[:, None], \
        {"S": S_new, "n": n_new}


# ---------------------------------------------------------------------------
# sLSTM (sequential; stabilized exponential gating; recurrent gates)
# ---------------------------------------------------------------------------


def slstm_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    H, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    # 128-align the GeGLU width (MXU tiling + even sharding over the mesh)
    ff = max(128, int(round(d * cfg.xlstm.proj_factor_slstm / 128)) * 128)
    t: Dict[str, ParamSpec] = {}
    for g in ("z", "i", "f", "o"):
        t[f"w_{g}"] = ParamSpec((d, d), ("embed", "heads"))
        # block-diagonal recurrent weights, one (dh, dh) block per head
        t[f"r_{g}"] = ParamSpec((H, dh, dh), ("heads", None, None),
                                init="normal", scale=0.4)
        t[f"b_{g}"] = ParamSpec((d,), ("heads",),
                                init="ones" if g == "f" else "zeros",
                                dtype="float32")
    t["norm_h"] = ParamSpec((d,), ("embed",), init="ones")
    # post-recurrence GeGLU FFN (proj factor 4/3, per the xLSTM paper)
    t["ff_gate"] = ParamSpec((d, ff), ("embed", "mlp"))
    t["ff_up"] = ParamSpec((d, ff), ("embed", "mlp"))
    t["ff_down"] = ParamSpec((ff, d), ("mlp", "embed"))
    return t


def slstm_cache_template(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    mk = lambda init: ParamSpec((batch, d), ("batch", "embed"), init=init,
                                dtype="float32")
    return {"c": mk("zeros"), "n": mk("zeros"), "h": mk("zeros"),
            "m": mk("zeros")}


def _slstm_step(p: Params, cfg: ModelConfig, state, pre):
    """One sLSTM timestep. state: dict(c,n,h,m) each (B,d) fp32.
    pre: dict of projected inputs w_g x_t (B,d)."""
    H, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    B = state["h"].shape[0]
    hh = state["h"].reshape(B, H, dh)

    def rec(g):
        r = jnp.einsum("BHd,Hde->BHe", hh, p[f"r_{g}"].astype(jnp.float32))
        return pre[g].astype(jnp.float32) + r.reshape(B, H * dh) + p[f"b_{g}"]

    z_t = jnp.tanh(rec("z"))
    o_t = jax.nn.sigmoid(rec("o"))
    i_pre, f_pre = rec("i"), rec("f")
    log_fgate = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_fgate + state["m"], i_pre)       # stabilizer
    i_t = jnp.exp(i_pre - m_new)
    f_t = jnp.exp(log_fgate + state["m"] - m_new)
    c_new = f_t * state["c"] + i_t * z_t
    n_new = f_t * state["n"] + i_t
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def _slstm_core(p: Params, x: jax.Array, cfg: ModelConfig, ctx: MeshContext,
                *, with_state: bool = False):
    B, S, d = x.shape
    pre = {g: jnp.einsum("BSE,EJ->BSJ", x, p[f"w_{g}"]) for g in "zifo"}
    state0 = {k: jnp.zeros((B, d), jnp.float32) for k in ("c", "n", "h", "m")}

    def step(state, pre_t):
        new = _slstm_step(p, cfg, state, pre_t)
        return new, new["h"]

    final, hs = jax.lax.scan(step, state0,
                             jax.tree.map(lambda t: t.swapaxes(0, 1), pre))
    h = hs.swapaxes(0, 1).astype(x.dtype)                    # (B,S,d)
    h = rms_norm(h, p["norm_h"], cfg.rms_eps)
    # GeGLU FFN
    g = jax.nn.gelu(jnp.einsum("BSE,EF->BSF", h, p["ff_gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("BSE,EF->BSF", h, p["ff_up"])
    out = jnp.einsum("BSF,FE->BSE", g * u, p["ff_down"])
    if with_state:
        return out, final
    return out


def slstm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  ctx: MeshContext) -> jax.Array:
    return _slstm_core(p, x, cfg, ctx, with_state=False)


def slstm_forward_with_state(p: Params, x: jax.Array, cfg: ModelConfig,
                             ctx: MeshContext):
    return _slstm_core(p, x, cfg, ctx, with_state=True)


def slstm_decode(p: Params, x: jax.Array, cache, cfg: ModelConfig,
                 ctx: MeshContext):
    xt = x[:, 0]
    pre = {g: jnp.einsum("BE,EJ->BJ", xt, p[f"w_{g}"]) for g in "zifo"}
    new = _slstm_step(p, cfg, cache, pre)
    h = rms_norm(new["h"].astype(x.dtype), p["norm_h"], cfg.rms_eps)
    g = jax.nn.gelu(jnp.einsum("BE,EF->BF", h, p["ff_gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("BE,EF->BF", h, p["ff_up"])
    out = jnp.einsum("BF,FE->BE", g * u, p["ff_down"])[:, None]
    return out, new
