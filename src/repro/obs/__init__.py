"""repro.obs — unified telemetry: span tracing, metrics, security audit.

Three planes, one subsystem (docs/observability.md):

* :mod:`repro.obs.trace`   — per-window span tracing (:class:`Tracer`,
  off-by-default via :data:`NULL_TRACER`), Chrome-trace JSON export;
* :mod:`repro.obs.metrics` — the process-wide :data:`REGISTRY` of named
  counters/gauges/histograms (absorbs the legacy global counters);
* :mod:`repro.obs.audit`   — the append-only security event stream owned
  by each :class:`repro.attest.KeyDirectory`.
"""
from repro.obs.audit import AuditEvent, AuditLog
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "AuditEvent", "AuditLog",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "NULL_TRACER", "NullTracer", "Span", "Tracer",
]
