"""repro.obs — unified telemetry: tracing, metrics, audit, live health.

Five planes, one subsystem (docs/observability.md):

* :mod:`repro.obs.trace`   — per-window span tracing (:class:`Tracer`,
  off-by-default via :data:`NULL_TRACER`), Chrome-trace JSON export;
* :mod:`repro.obs.metrics` — the process-wide :data:`REGISTRY` of named
  counters/gauges/histograms (absorbs the legacy global counters), plus
  the compiled-program :func:`dispatch_count` launch counter;
* :mod:`repro.obs.audit`   — the append-only security event stream owned
  by each :class:`repro.attest.KeyDirectory`;
* :mod:`repro.obs.monitor` — :class:`PipelineMonitor` sliding-window
  stage health + the SLO/stall :class:`Watchdog`;
* :mod:`repro.obs.export`  — Prometheus/JSON exporters and the stdlib
  HTTP scrape endpoint (:func:`serve_metrics`).
"""
from repro.obs.audit import AuditEvent, AuditLog
from repro.obs.export import (MetricsServer, prometheus_text, serve_metrics,
                              snapshot_json)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY, dispatch_count,
                               reset_dispatch_count)
from repro.obs.monitor import (Breach, NULL_MONITOR, NullMonitor,
                               PipelineMonitor, SLORule, Watchdog)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "AuditEvent", "AuditLog",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "dispatch_count", "reset_dispatch_count",
    "NULL_TRACER", "NullTracer", "Span", "Tracer",
    "Breach", "NULL_MONITOR", "NullMonitor", "PipelineMonitor",
    "SLORule", "Watchdog",
    "MetricsServer", "prometheus_text", "serve_metrics", "snapshot_json",
]
