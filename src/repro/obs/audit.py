"""Security audit log: an append-only, in-order stream of security events.

The engine's *security* behaviour — which rows failed their MAC, when
every edge key rotated, which workers were revoked or evicted, which
quotes were rejected, whether a nonce space was ever exhausted — was
previously visible only as aggregate counters.  The audit log records
each of those events **as it happens**, with a strictly increasing
sequence number, so tests (and operators) can assert exact counts and
exact ordering: k tampered rows must yield exactly k ``mac_failure``
events, and a revocation lands between precisely the rekeys that
preceded and followed it.

The :class:`repro.attest.directory.KeyDirectory` owns one log per trust
domain and records the key-lifecycle events itself (rekey, revocation,
quote_rejected, nonce_exhausted); the streaming engine appends the
data-plane events (mac_failure with row counter + epoch + stage,
eviction when a revoked worker is first skipped at dispatch).  Events
are plain data — recording is an append, never an I/O call — and the
log is bounded (oldest events drop past ``max_events``; ``dropped``
counts them) so a hostile stream of tampered rows cannot grow memory
without bound.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

#: The closed vocabulary of event kinds — ``record`` rejects typos so a
#: misspelled kind cannot silently create an unqueryable event class.
KINDS = (
    "mac_failure",      # a row failed its CW-MAC check and was dropped
    "rekey",            # KeyDirectory.advance_epoch ratcheted every edge
    "revocation",       # a worker id was quarantined (sessions torn down)
    "eviction",         # the engine first skipped a revoked worker
    "quote_rejected",   # a quote failed policy verification
    "nonce_exhausted",  # a counter reservation would wrap the nonce space
    "slo_breach",       # a Watchdog SLO rule crossed its declared limit
    "stall",            # no window progressed for the rule's grace period
    "worker_failed",    # a worker was lost mid-share (crash or stall)
    "share_retried",    # a share was re-dispatched to the same worker
    "share_failover",   # a share moved to a survivor / spare / backup
    "window_replayed",  # retained ingress rows were re-executed
)


@dataclass(frozen=True)
class AuditEvent:
    """One security event: ``seq`` is the in-order position, ``detail``
    the kind-specific payload (row/epoch/stage for mac_failure, the new
    epoch for rekey, worker + dropped edges for revocation, ...)."""
    seq: int
    kind: str
    detail: Dict[str, Any] = field(default_factory=dict)

    def __str__(self) -> str:
        d = " ".join(f"{k}={v}" for k, v in self.detail.items())
        return f"#{self.seq} {self.kind}" + (f" {d}" if d else "")


class AuditLog:
    """Append-only in-order event stream, queryable by kind."""

    def __init__(self, max_events: int = 65536):
        self._events: List[AuditEvent] = []
        self._seq = 0
        self.max_events = max(1, int(max_events))
        self.dropped = 0                      # evicted past max_events

    # ------------------------------------------------------------ recording

    def record(self, kind: str, **detail) -> AuditEvent:
        if kind not in KINDS:
            raise ValueError(f"unknown audit event kind {kind!r}; "
                             f"expected one of {KINDS}")
        ev = AuditEvent(seq=self._seq, kind=kind, detail=detail)
        self._seq += 1
        self._events.append(ev)
        if len(self._events) > self.max_events:
            del self._events[0]
            self.dropped += 1
        return ev

    # -------------------------------------------------------------- queries

    def events(self, kind: Optional[str] = None) -> List[AuditEvent]:
        """All retained events in stream order, optionally one kind."""
        if kind is None:
            return list(self._events)
        if kind not in KINDS:
            raise ValueError(f"unknown audit event kind {kind!r}; "
                             f"expected one of {KINDS}")
        return [e for e in self._events if e.kind == kind]

    def counts(self) -> Dict[str, int]:
        """Retained events per kind (absent kinds included as 0)."""
        out = {k: 0 for k in KINDS}
        for e in self._events:
            out[e.kind] += 1
        return out

    def kind_sequence(self, *kinds: str) -> List[str]:
        """The in-order subsequence of event kinds restricted to
        ``kinds`` (all kinds when empty) — the ordering assertion
        primitive: ``log.kind_sequence("rekey", "revocation")``."""
        keep = set(kinds) if kinds else set(KINDS)
        return [e.kind for e in self._events if e.kind in keep]

    def summary(self) -> Dict[str, Any]:
        """Compact dict for ``Pipeline.report()``: total + per-kind
        counts (zero kinds omitted) + how many events were dropped."""
        counts = {k: n for k, n in self.counts().items() if n}
        return {"events": len(self._events), "dropped": self.dropped,
                **counts}

    def dump(self) -> List[Dict[str, Any]]:
        """Events as plain dicts (JSON-ready)."""
        return [{"seq": e.seq, "kind": e.kind, **e.detail}
                for e in self._events]

    def clear(self) -> None:
        """Drop every retained event and the drop count; ``seq`` keeps
        counting (a cleared log is still the same stream, so ordering
        assertions across a clear stay meaningful)."""
        self._events.clear()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self):
        return iter(self._events)
