"""Exporters: Prometheus text exposition + JSON snapshots over HTTP.

Renders any :class:`~repro.obs.monitor.PipelineMonitor` snapshot and the
process-wide :data:`~repro.obs.metrics.REGISTRY` in two formats:

* :func:`prometheus_text` — the Prometheus text exposition format
  (version 0.0.4): stage-scoped series carry a ``stage="..."`` label
  (``repro_stage_windows_per_second{stage="sgx_mapper"}``), registry
  histograms export as summaries with ``quantile`` labels, and every
  registry instrument flattens to a sanitized ``repro_*`` name;
* :func:`snapshot_json` — the monitor snapshot + registry dump as one
  JSON-ready dict (what CI uploads next to the bench artifacts).

:func:`serve_metrics` serves both from a stdlib ``http.server`` thread —
``/metrics`` (Prometheus), ``/health`` (liveness + watchdog verdict),
``/snapshot`` (JSON) — so a running pipeline is scrapeable with zero
third-party dependencies.  ``port=0`` binds an ephemeral port (tests);
the returned :class:`MetricsServer` exposes ``.port``/``.url`` and
``.stop()``, and works as a context manager.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional

from repro.obs.metrics import REGISTRY, Counter, Gauge, Histogram
from repro.obs.monitor import NULL_MONITOR

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_STAGE_RE = re.compile(r"^pipeline\.stage\.(?P<stage>.+)\.(?P<metric>[^.]+)$")

#: monitor stage-stat key -> (prometheus metric suffix, HELP text)
_STAGE_STATS = (
    ("windows_per_s", "windows_per_second",
     "Sliding-window stage throughput in windows/s"),
    ("rows_per_s", "rows_per_second",
     "Sliding-window stage throughput in rows/s"),
    ("mbps", "mbytes_per_second",
     "Sliding-window stage plaintext throughput in MB/s"),
    ("p50_s", "window_latency_p50_seconds",
     "Sliding-window p50 per-window stage latency"),
    ("p95_s", "window_latency_p95_seconds",
     "Sliding-window p95 per-window stage latency"),
    ("queue_rows", "queue_rows",
     "Rows buffered at the stage boundary (last window)"),
    ("worker_skew", "worker_skew",
     "Max/mean per-worker row share over the sliding window (1.0=even)"),
    ("mac_failure_rate", "mac_failure_rate",
     "Fraction of rows failing MAC verification (sliding window)"),
    ("dispatches_per_window", "dispatches_per_window",
     "Compiled-program launches per window at this hop"),
    ("epoch_lag", "epoch_lag",
     "Directory epoch minus the stage's oldest in-flight epoch"),
)


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _label(value: str) -> str:
    """Escape a Prometheus label value."""
    return value.replace("\\", "\\\\").replace('"', '\\"') \
                .replace("\n", "\\n")


def _fmt(v: Any) -> str:
    if v is None:
        return "NaN"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def prometheus_text(registry=None, monitor=None) -> str:
    """Render the registry + monitor snapshot as Prometheus text
    exposition (format version 0.0.4)."""
    registry = REGISTRY if registry is None else registry
    monitor = NULL_MONITOR if monitor is None else monitor
    lines: List[str] = []

    def head(name: str, kind: str, help_: str) -> None:
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {kind}")

    # ---- registry instruments: stage-scoped names become labeled series
    stage_series: Dict[str, List] = {}
    flat: List = []
    for name in registry.names():
        inst = registry.get(name)
        m = _STAGE_RE.match(name)
        if m:
            key = m.group("metric")
            stage_series.setdefault(key, []).append(
                (m.group("stage"), inst))
        else:
            flat.append((name, inst))

    for key in sorted(stage_series):
        entries = stage_series[key]
        kind = ("counter" if isinstance(entries[0][1], Counter)
                else "gauge" if isinstance(entries[0][1], Gauge)
                else "summary")
        base = f"repro_pipeline_stage_{_sanitize(key)}"
        head(base, kind, f"Registry instrument pipeline.stage.*.{key}")
        for stage, inst in entries:
            lab = f'stage="{_label(stage)}"'
            if isinstance(inst, Histogram):
                for q in (50, 95, 99):
                    lines.append(
                        f'{base}{{{lab},quantile="{q / 100}"}} '
                        f"{_fmt(inst.percentile(q))}")
                lines.append(f"{base}_count{{{lab}}} {inst.count}")
                lines.append(f"{base}_sum{{{lab}}} {_fmt(inst.total)}")
            else:
                lines.append(f"{base}{{{lab}}} {_fmt(inst.value)}")

    for name, inst in flat:
        base = f"repro_{_sanitize(name)}"
        if isinstance(inst, Histogram):
            head(base, "summary", f"Registry histogram {name}")
            for q in (50, 95, 99):
                lines.append(f'{base}{{quantile="{q / 100}"}} '
                             f"{_fmt(inst.percentile(q))}")
            lines.append(f"{base}_count {inst.count}")
            lines.append(f"{base}_sum {_fmt(inst.total)}")
        else:
            kind = "counter" if isinstance(inst, Counter) else "gauge"
            head(base, kind, f"Registry {kind} {name}")
            lines.append(f"{base} {_fmt(inst.value)}")

    # ---- monitor sliding-window stage health
    snap = monitor.snapshot() if getattr(monitor, "enabled", False) else None
    if snap and snap["stages"]:
        for key, suffix, help_ in _STAGE_STATS:
            base = f"repro_stage_{suffix}"
            head(base, "gauge", help_)
            for stage in sorted(snap["stages"]):
                stats = snap["stages"][stage]
                if stats is None or stats.get(key) is None:
                    continue
                lines.append(
                    f'{base}{{stage="{_label(stage)}"}} '
                    f"{_fmt(stats[key])}")
    if snap:
        # "repro_monitor_", not "repro_pipeline_": the snapshot mirrors
        # registry totals (host_syncs, dispatches) whose flat names
        # already own the repro_pipeline_*/repro_device_* namespace.
        for key, v in sorted(snap["pipeline"].items()):
            if isinstance(v, dict):
                # nested group (e.g. "ft": fault-tolerance totals) —
                # flatten to repro_monitor_<group>_<metric>
                for sub, sv in sorted(v.items()):
                    base = f"repro_monitor_{_sanitize(key)}_" \
                           f"{_sanitize(sub)}"
                    head(base, "gauge", f"Pipeline-wide {key}.{sub}")
                    lines.append(f"{base} {_fmt(sv)}")
                continue
            base = f"repro_monitor_{_sanitize(key)}"
            head(base, "gauge", f"Pipeline-wide {key}")
            lines.append(f"{base} {_fmt(v)}")
        wd = snap.get("watchdog")
        if wd is not None:
            head("repro_slo_breached", "gauge",
                 "1 while any watchdog SLO rule is latched breached")
            lines.append(
                f"repro_slo_breached {1 if wd['breached'] else 0}")
    return "\n".join(lines) + "\n"


def snapshot_json(monitor=None, registry=None) -> Dict[str, Any]:
    """The monitor snapshot + registry dump as one JSON-ready dict."""
    registry = REGISTRY if registry is None else registry
    monitor = NULL_MONITOR if monitor is None else monitor
    return {"monitor": monitor.snapshot(), "registry": registry.snapshot()}


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-metrics/1"

    def do_GET(self):                                   # noqa: N802
        mon = self.server.monitor                       # type: ignore
        reg = self.server.registry                      # type: ignore
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            body = prometheus_text(reg, mon).encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path == "/health":
            breaches = mon.check() if getattr(mon, "enabled", False) else []
            snap = mon.snapshot()
            wd = snap.get("watchdog")
            latched = wd["breached"] if wd else []
            status = "ok"
            if any(b.kind == "stall" for b in breaches) or \
                    any("stall" in r for r in latched):
                status = "stalled"
            elif latched:
                status = "degraded"
            body = json.dumps({
                "status": status, "breached": latched,
                "windows_total": snap["pipeline"].get("windows_total", 0),
                "uptime_s": snap["pipeline"].get("uptime_s"),
            }).encode()
            ctype = "application/json"
        elif path == "/snapshot":
            body = json.dumps(snapshot_json(mon, reg), indent=1).encode()
            ctype = "application/json"
        else:
            self.send_error(404, "try /metrics, /health or /snapshot")
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):                  # silence stderr
        return None


class MetricsServer:
    """A scrape endpoint on a daemon thread; ``port=0`` = ephemeral."""

    def __init__(self, monitor=None, registry=None,
                 host: str = "127.0.0.1", port: int = 0):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.monitor = NULL_MONITOR if monitor is None else monitor
        self._httpd.registry = REGISTRY if registry is None else registry
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def serve_metrics(port: int = 0, monitor=None, registry=None,
                  host: str = "127.0.0.1") -> MetricsServer:
    """Start serving ``/metrics``, ``/health`` and ``/snapshot`` on a
    daemon thread; returns the running :class:`MetricsServer`."""
    return MetricsServer(monitor=monitor, registry=registry,
                         host=host, port=port)
