"""Metrics registry: named counters, gauges, and histograms.

One process-wide :data:`REGISTRY` absorbs the scattered global counters
that predate it (``pipeline.host_sync_count``,
``collectives.exchange_call_count``, ``aead.fastpath_stats`` — all kept
as thin shims over registered counters), and adds the streaming-latency
histograms (p50/p95/p99 per stage) and queue-depth gauges the elastic
autoscaling controller will consume as its feedback signals.

Design constraints, in order:

* **hot-path cheap** — instruments are plain objects with one mutable
  slot; callers resolve them ONCE (``c = REGISTRY.counter(name)``) and
  then call ``c.inc()`` per event, so the per-event cost is an attribute
  add, not a dict lookup;
* **one namespace** — a name is bound to exactly one instrument kind;
  re-requesting it returns the SAME object (shims and tests can reset a
  counter without invalidating references held by the hot path), and
  requesting it as a different kind is an error, not a shadow;
* **stdlib only** — this module imports nothing from the rest of the
  repo, so every layer (crypto, dist, core, attest) can depend on it
  without cycles.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotonic event count (resettable by tests/benchmarks only)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written level (queue depth, buffered rows, pool size)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Streaming distribution with exact percentiles.

    Samples are kept in sorted order (insertion is a bisect — windows
    arrive a few per second, not millions), so ``percentile`` is an
    index, not a sort.  ``max_samples`` bounds memory on unbounded
    streams by dropping the OLDEST samples (the percentiles then cover a
    sliding suffix — exactly what a latency SLO controller wants).
    """

    __slots__ = ("name", "_sorted", "_order", "count", "total",
                 "max_samples")

    def __init__(self, name: str, max_samples: int = 4096):
        self.name = name
        self._sorted: List[float] = []   # ascending sample values
        self._order: List[float] = []    # arrival order (for eviction)
        self.count = 0                   # lifetime observations
        self.total = 0.0                 # lifetime sum
        self.max_samples = max_samples

    def observe(self, v: Number) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        bisect.insort(self._sorted, v)
        self._order.append(v)
        if len(self._order) > self.max_samples:
            old = self._order.pop(0)
            del self._sorted[bisect.bisect_left(self._sorted, old)]

    def percentile(self, q: float) -> Optional[float]:
        """Exact q-th percentile (0..100) of the retained samples;
        None before the first observation."""
        if not self._sorted:
            return None
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile wants 0..100, got {q}")
        idx = min(len(self._sorted) - 1,
                  int(round(q / 100.0 * (len(self._sorted) - 1))))
        return self._sorted[idx]

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def summary(self) -> Dict[str, Optional[float]]:
        """{count, mean, p50, p95, p99, max} — None-valued before data."""
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "max": self._sorted[-1] if self._sorted else None}

    def reset(self) -> None:
        self._sorted.clear()
        self._order.clear()
        self.count = 0
        self.total = 0.0


class MetricsRegistry:
    """Name -> instrument map with get-or-create semantics."""

    def __init__(self):
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name)
            self._instruments[name] = inst
        elif type(inst) is not cls:
            raise TypeError(
                f"metric {name!r} is a {type(inst).__name__}, requested "
                f"as {cls.__name__} — one name, one instrument kind")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def get(self, name: str):
        """The instrument registered under ``name`` (None if absent) —
        read-side access that never creates."""
        return self._instruments.get(name)

    def snapshot(self) -> Dict[str, object]:
        """Point-in-time dump: counters/gauges -> value, histograms ->
        their :meth:`Histogram.summary` dict."""
        out: Dict[str, object] = {}
        for name, inst in sorted(self._instruments.items()):
            out[name] = inst.summary() if isinstance(inst, Histogram) \
                else inst.value
        return out

    def reset(self, prefix: str = "") -> None:
        """Zero every instrument whose name starts with ``prefix`` —
        instruments stay registered (hot-path references stay valid)."""
        for name, inst in self._instruments.items():
            if name.startswith(prefix):
                inst.reset()


#: The process-wide default registry every layer registers into.
REGISTRY = MetricsRegistry()

#: Every compiled-program launch site (aead fastpath seal/open_many,
#: enclave_map, eager cwmac, dist.exchange) increments this one counter
#: in its eager Python wrapper — NEVER inside traced code, where an
#: ``inc()`` would fire once at trace time and then vanish into the
#: compiled program.  Per-site breakdowns live under
#: ``device.dispatches.<site>``.
DISPATCHES = REGISTRY.counter("device.dispatches")


def dispatch_count() -> int:
    """Total compiled-program launches since the last reset — the
    megakernel roadmap item's regression signal next to
    ``host_sync_count()``: fusing kernels must DROP this number."""
    return DISPATCHES.value


def reset_dispatch_count() -> None:
    """Zero the global dispatch counter and every per-site breakdown."""
    REGISTRY.reset("device.dispatches")
