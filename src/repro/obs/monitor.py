"""Live pipeline health: sliding-window stage stats + SLO watchdog.

:class:`PipelineMonitor` watches a *running* pipeline — where the
:data:`~repro.obs.metrics.REGISTRY` instruments and the
:class:`~repro.obs.audit.AuditLog` accumulate lifetime totals, the
monitor maintains **sliding-window** aggregates (windows/s, MB/s,
p50/p95 window latency, queue depth, per-worker row-count skew,
mac-failure and rekey/eviction rates, epoch lag), updated once per
window by a single ``record_window`` call from the engine.  That is the
live feedback signal the ROADMAP's elastic-autoscaling controller needs,
and it is what the exporters in :mod:`repro.obs.export` serve over HTTP.

Cost model mirrors the tracer: the engine holds :data:`NULL_MONITOR`
(``enabled=False``) unless a real monitor is attached, so the disabled
path is one attribute check per window.  Enabled, each record is a deque
append plus O(window) evictions — the ``pipeline.monitored`` bench row
enforces the <= 3% budget.

:class:`Watchdog` evaluates declarative :class:`SLORule` limits (max p95
latency, min throughput, max queue depth, mac-failure-rate ceiling, and
stall = no window progress for T seconds) against the monitor's sliding
stats.  A rule fires its ordered callbacks ONCE per breach — it re-arms
only after the condition recovers — and writes the matching
``slo_breach``/``stall`` event into the audit log, so breaches land in
the same ordered security stream as rekeys and revocations.  Clocks are
injectable (``clock=``) so stalls are testable without sleeping.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.obs.audit import AuditLog
from repro.obs.metrics import REGISTRY


class NullMonitor:
    """The disabled monitor: every operation is a no-op.

    ``enabled`` is False so the engine skips even building the per-window
    kwargs; a NullMonitor never allocates.
    """

    enabled = False

    def attach(self, pipeline) -> None:
        return None

    def record_window(self, stage: str, **kw) -> None:
        return None

    def snapshot(self, now: Optional[float] = None) -> Dict[str, Any]:
        return {"stages": {}, "pipeline": {}, "watchdog": None}


#: The module-wide disabled monitor the engine defaults to.
NULL_MONITOR = NullMonitor()


class _StageWindow:
    """Sliding-window aggregates for one stage (or ingress/egress hop)."""

    __slots__ = ("samples", "rows", "ok_rows", "bytes", "seconds",
                 "dispatches", "worker_rows", "queue_rows", "epoch_lag",
                 "total_windows", "total_rows")

    def __init__(self):
        # each sample: (t, rows, ok_rows, bytes, seconds, dispatches,
        #               worker_rows-dict-or-None)
        self.samples: deque = deque()
        self.rows = 0                 # running sums over the deque
        self.ok_rows = 0
        self.bytes = 0
        self.seconds = 0.0
        self.dispatches = 0
        self.worker_rows: Dict[Any, int] = {}
        self.queue_rows: Optional[int] = None     # last observed
        self.epoch_lag: Optional[int] = None      # last observed
        self.total_windows = 0                    # lifetime
        self.total_rows = 0

    def add(self, t, rows, ok_rows, nbytes, seconds, dispatches, wrows):
        self.samples.append((t, rows, ok_rows, nbytes, seconds,
                             dispatches, wrows))
        self.rows += rows
        self.ok_rows += ok_rows
        self.bytes += nbytes
        self.seconds += seconds
        self.dispatches += dispatches
        if wrows:
            for w, r in wrows.items():
                self.worker_rows[w] = self.worker_rows.get(w, 0) + r
        self.total_windows += 1
        self.total_rows += rows

    def evict(self, cutoff: float, max_samples: int) -> None:
        q = self.samples
        while q and (q[0][0] < cutoff or len(q) > max_samples):
            t, rows, ok, nb, sec, disp, wrows = q.popleft()
            self.rows -= rows
            self.ok_rows -= ok
            self.bytes -= nb
            self.seconds -= sec
            self.dispatches -= disp
            if wrows:
                for w, r in wrows.items():
                    left = self.worker_rows.get(w, 0) - r
                    if left > 0:
                        self.worker_rows[w] = left
                    else:
                        self.worker_rows.pop(w, None)


class PipelineMonitor:
    """Per-stage sliding-window health, updated once per window.

    The engine calls :meth:`record_window` after each stage round (and
    for the ingress/egress hops under the pseudo-stage names
    ``"ingress"``/``"egress"``); everything else — audit-event rates,
    epoch lag, watchdog checks — piggybacks on that call, so a monitored
    run adds no extra host syncs and no background threads.

    ``window_seconds`` is the sliding horizon; ``max_samples`` bounds
    memory per stage regardless of rate.  ``clock`` is injectable for
    tests (defaults to ``time.monotonic``).
    """

    enabled = True

    def __init__(self, window_seconds: float = 60.0,
                 max_samples: int = 512,
                 clock: Optional[Callable[[], float]] = None):
        self.window_seconds = float(window_seconds)
        self.max_samples = int(max_samples)
        self._clock = clock or time.monotonic
        self._lock = threading.Lock()     # snapshot() runs on HTTP threads
        self._stages: Dict[str, _StageWindow] = {}
        self._t0 = self._clock()
        self.last_progress = self._t0     # last record_window of any stage
        self.windows_total = 0
        self._audit: Optional[AuditLog] = None
        self._audit_seen = 0              # next unseen audit seq
        self._audit_times: Dict[str, deque] = {}
        self._directory = None            # epoch source (may stay None)
        self._watchdogs: List["Watchdog"] = []

    # ----------------------------------------------------------- attachment

    def attach(self, pipeline) -> None:
        """Bind to a pipeline's key directory (audit log + epoch source).

        Re-attaching to another pipeline re-binds the audit stream; the
        sliding stats continue (useful across ``scale_stage`` rebuilds).
        """
        directory = getattr(pipeline, "directory", None)
        with self._lock:
            self._directory = directory
            audit = getattr(directory, "audit", None)
            if audit is not self._audit:
                self._audit = audit
                self._audit_seen = audit._seq if audit is not None else 0
            self.last_progress = self._clock()

    def watch(self, watchdog: "Watchdog") -> "Watchdog":
        self._watchdogs.append(watchdog)
        return watchdog

    # ------------------------------------------------------------ recording

    def record_window(self, stage: str, *, rows: int, ok_rows:
                      Optional[int] = None, bytes: int = 0,
                      seconds: float = 0.0, queue_rows:
                      Optional[int] = None, worker_rows:
                      Optional[Dict[Any, int]] = None,
                      min_epoch: Optional[int] = None,
                      dispatches: int = 0) -> None:
        """Fold one completed window into the stage's sliding stats."""
        now = self._clock()
        ok = rows if ok_rows is None else ok_rows
        with self._lock:
            sw = self._stages.get(stage)
            if sw is None:
                sw = self._stages[stage] = _StageWindow()
            sw.add(now, rows, ok, bytes, seconds, dispatches, worker_rows)
            sw.evict(now - self.window_seconds, self.max_samples)
            if queue_rows is not None:
                sw.queue_rows = queue_rows
            if min_epoch is not None and self._directory is not None:
                sw.epoch_lag = int(self._directory.epoch) - int(min_epoch)
            self.last_progress = now
            self.windows_total += 1
            self._ingest_audit(now)
        for wd in self._watchdogs:
            wd.check(now)

    def _ingest_audit(self, now: float) -> None:
        """Stamp newly appended audit events with their arrival time so
        per-kind rates can slide (AuditEvents carry order, not time)."""
        log = self._audit
        if log is not None and log._seq != self._audit_seen:
            for ev in log.events():
                if ev.seq >= self._audit_seen:
                    self._audit_times.setdefault(ev.kind,
                                                 deque()).append(now)
            self._audit_seen = log._seq
        cutoff = now - self.window_seconds
        for q in self._audit_times.values():
            while q and (q[0] < cutoff or len(q) > self.max_samples):
                q.popleft()

    # -------------------------------------------------------------- queries

    def _span(self, now: float) -> float:
        """The effective averaging horizon: elapsed time since attach,
        clamped to the sliding window and away from zero."""
        return max(min(now - self._t0, self.window_seconds), 1e-9)

    def stage_stats(self, stage: str,
                    now: Optional[float] = None) -> Optional[Dict[str, Any]]:
        """Sliding-window stats for one stage; None before its first
        window."""
        now = self._clock() if now is None else now
        with self._lock:
            return self._stage_stats_locked(stage, now)

    def _stage_stats_locked(self, stage, now):
        sw = self._stages.get(stage)
        if sw is None:
            return None
        sw.evict(now - self.window_seconds, self.max_samples)
        span = self._span(now)
        n = len(sw.samples)
        secs = sorted(s[4] for s in sw.samples)

        def pct(q):
            if not secs:
                return None
            return secs[min(n - 1, int(round(q / 100.0 * (n - 1))))]

        skew = None
        if sw.worker_rows:
            per_w = list(sw.worker_rows.values())
            mean = sum(per_w) / len(per_w)
            skew = (max(per_w) / mean) if mean else None
        return {
            "windows": n,
            "windows_total": sw.total_windows,
            "windows_per_s": n / span,
            "rows_per_s": sw.rows / span,
            "mbps": (sw.bytes / span) / 1e6,
            "p50_s": pct(50),
            "p95_s": pct(95),
            "queue_rows": sw.queue_rows,
            "worker_rows": dict(sw.worker_rows),
            "worker_skew": skew,
            "mac_failures": sw.rows - sw.ok_rows,
            "mac_failure_rate": ((sw.rows - sw.ok_rows) / sw.rows)
            if sw.rows else 0.0,
            "dispatches": sw.dispatches,
            "dispatches_per_window": (sw.dispatches / n) if n else 0.0,
            "epoch_lag": sw.epoch_lag,
        }

    def snapshot(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Point-in-time health dict: per-stage sliding stats, pipeline-
        wide audit rates + registry totals, watchdog state. JSON-ready."""
        now = self._clock() if now is None else now
        with self._lock:
            self._ingest_audit(now)
            span = self._span(now)
            stages = {name: self._stage_stats_locked(name, now)
                      for name in self._stages}
            rates = {f"{kind}_per_s": len(q) / span
                     for kind, q in sorted(self._audit_times.items()) if q}
            host_syncs = REGISTRY.get("pipeline.host_syncs")
            dispatches = REGISTRY.get("device.dispatches")
            # fault-tolerance totals (repro.ft): all zero / absent until
            # a retry policy or chaos plan is attached to a run
            ft = {}
            for short in ("retries", "failovers", "backups", "replays",
                          "worker_failures", "enroll_failures"):
                c = REGISTRY.get(f"ft.{short}")
                if c is not None:
                    ft[short] = c.value
            g = REGISTRY.get("ft.replay.retained_rows")
            if g is not None:
                ft["replay_retained_rows"] = g.value
            pipe = {
                "uptime_s": now - self._t0,
                "windows_total": self.windows_total,
                "last_progress_age_s": now - self.last_progress,
                "host_syncs": host_syncs.value if host_syncs else 0,
                "dispatches": dispatches.value if dispatches else 0,
                **({"ft": ft} if ft else {}),
                **rates,
            }
        wd = None
        if self._watchdogs:
            wd = {"rules": sum(len(w.rules) for w in self._watchdogs),
                  "breached": sorted(r for w in self._watchdogs
                                     for r in w.breached())}
        return {"t": now, "stages": stages, "pipeline": pipe,
                "watchdog": wd}

    def check(self, now: Optional[float] = None) -> List["Breach"]:
        """Run every attached watchdog (the stall path: nothing calls
        ``record_window`` during a stall, so poll this — the HTTP
        ``/health`` endpoint does)."""
        now = self._clock() if now is None else now
        out: List[Breach] = []
        for wd in self._watchdogs:
            out.extend(wd.check(now))
        return out


# ------------------------------------------------------------------ watchdog


@dataclass(frozen=True)
class SLORule:
    """One declarative service-level objective.

    Set any subset of the limit fields; the rule breaches when ANY set
    limit is crossed.  ``stage=None`` evaluates the rule against every
    stage the monitor has seen (the breach detail names the offender).
    ``stall_seconds`` is pipeline-wide: no window progressed anywhere
    for that long.
    """
    name: str
    stage: Optional[str] = None
    max_p95_seconds: Optional[float] = None
    min_windows_per_s: Optional[float] = None
    min_mbps: Optional[float] = None
    max_queue_rows: Optional[float] = None
    max_mac_failure_rate: Optional[float] = None
    stall_seconds: Optional[float] = None


@dataclass(frozen=True)
class Breach:
    """One fired SLO violation (also recorded into the audit log)."""
    rule: str
    kind: str                     # "slo_breach" | "stall"
    stage: Optional[str]
    metric: str
    value: Optional[float]
    limit: float
    t: float
    detail: Dict[str, Any] = field(default_factory=dict)


class Watchdog:
    """Evaluates :class:`SLORule` limits against a monitor's sliding
    stats; fires ordered callbacks once per breach transition.

    A rule that breaches stays latched (no repeat fire while the
    condition persists) and re-arms when a later check finds it
    recovered — "trips exactly once" per incident.  Every fire records
    the matching ``slo_breach``/``stall`` audit event into the
    pipeline's audit log (or a private one when unattached), so SLO
    violations interleave with rekeys/revocations in one ordered stream.
    """

    def __init__(self, monitor: PipelineMonitor,
                 rules: Sequence[SLORule],
                 on_breach: Sequence[Callable[[Breach], None]] = (),
                 audit: Optional[AuditLog] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.monitor = monitor
        self.rules = list(rules)
        self.on_breach = list(on_breach)
        self._audit = audit
        self._clock = clock or monitor._clock
        self._latched: Dict[str, bool] = {}
        self.fired: List[Breach] = []       # every breach ever fired
        monitor.watch(self)

    def breached(self) -> List[str]:
        """Names of rules currently latched in the breached state."""
        return [name for name, b in self._latched.items() if b]

    @property
    def audit(self) -> AuditLog:
        if self._audit is not None:
            return self._audit
        mon_audit = self.monitor._audit
        if mon_audit is not None:
            return mon_audit
        self._audit = AuditLog()            # unattached fallback
        return self._audit

    # ----------------------------------------------------------- evaluation

    def _violation(self, rule: SLORule, now: float):
        """-> (kind, stage, metric, value, limit) or None."""
        m = self.monitor
        if rule.stall_seconds is not None:
            age = now - m.last_progress
            if age > rule.stall_seconds:
                return ("stall", rule.stage, "last_progress_age_s",
                        age, rule.stall_seconds)
        stages = [rule.stage] if rule.stage is not None \
            else sorted(m._stages)
        for st in stages:
            stats = m.stage_stats(st, now)
            if stats is None:
                continue                    # no data yet: not a breach
            checks = (
                ("p95_s", stats["p95_s"], rule.max_p95_seconds, 1),
                ("windows_per_s", stats["windows_per_s"],
                 rule.min_windows_per_s, -1),
                ("mbps", stats["mbps"], rule.min_mbps, -1),
                ("queue_rows", stats["queue_rows"],
                 rule.max_queue_rows, 1),
                ("mac_failure_rate", stats["mac_failure_rate"],
                 rule.max_mac_failure_rate, 1),
            )
            for metric, value, limit, sign in checks:
                if limit is None or value is None:
                    continue
                if (sign > 0 and value > limit) or \
                        (sign < 0 and value < limit):
                    return ("slo_breach", st, metric, value, limit)
        return None

    def check(self, now: Optional[float] = None) -> List[Breach]:
        """Evaluate every rule; fire callbacks + audit events for rules
        newly entering the breached state; re-arm recovered rules."""
        now = self._clock() if now is None else now
        fired: List[Breach] = []
        for rule in self.rules:
            viol = self._violation(rule, now)
            was = self._latched.get(rule.name, False)
            if viol is not None and not was:
                kind, stage, metric, value, limit = viol
                self._latched[rule.name] = True
                b = Breach(rule=rule.name, kind=kind, stage=stage,
                           metric=metric,
                           value=None if value is None else float(value),
                           limit=float(limit), t=now)
                self.audit.record(kind, rule=b.rule, stage=b.stage,
                                  metric=b.metric, value=b.value,
                                  limit=b.limit)
                self.fired.append(b)
                fired.append(b)
                for cb in self.on_breach:
                    cb(b)
            elif viol is None and was:
                self._latched[rule.name] = False
        return fired
