"""Per-window span tracing for the streaming engine.

A :class:`Tracer` records **spans** — named intervals with monotonic
timestamps, a parent/child structure, a track (Chrome "thread" lane), and
window/epoch/worker attribution args — around the engine's units of work:
ingress seal, each stage's per-worker open->op->seal share, the one
deferred-verdict host sync per window, merge, reduce folds, rekey flips,
and exchange rounds.  Export targets:

* :meth:`Tracer.export_chrome` — the Chrome trace-event JSON format
  (load in ``chrome://tracing`` or https://ui.perfetto.dev);
* :meth:`Tracer.timeline` — a human-readable indented text timeline.

Tracing is **off by default and zero-cost when disabled**: code holds
:data:`NULL_TRACER` (a :class:`NullTracer`) unless a real tracer is
passed in, and its ``span()``/``instant()`` are no-ops returning one
shared reusable context manager — no span objects, no clock reads, no
list growth.  The pipeline bench (``pipeline.traced`` row) enforces the
<= 2% enabled / parity disabled budget.

A deliberate caveat: spans around *asynchronously dispatched* device
work (category ``"dispatch"``) measure enqueue time, not execution —
execution lands in the per-window ``sync.verdicts`` span, which brackets
the engine's single ``block_until_ready`` per window.  The span args
carry that distinction so the timeline stays honest.
"""
from __future__ import annotations

import io
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class Span:
    """One recorded interval (times are seconds since the tracer's t0)."""
    id: int
    name: str
    cat: str
    track: str                    # Chrome "thread" lane, e.g. "s3/w1"
    start: float
    end: Optional[float] = None   # None while open / for instants
    parent: Optional[int] = None
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return 0.0 if self.end is None else self.end - self.start


class _NoopSpan:
    """The one shared context manager NullTracer hands out."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class NullTracer:
    """The disabled tracer: every operation is a no-op.

    ``enabled`` is False so hot paths that want to skip even arg
    construction can guard on it; paths that don't bother still pay only
    a method call returning a shared singleton.
    """

    enabled = False

    def span(self, name: str, cat: str = "pipeline", track: str = "main",
             **args) -> _NoopSpan:
        return _NOOP_SPAN

    def instant(self, name: str, cat: str = "pipeline",
                track: str = "main", **args) -> None:
        return None

    def counter(self, name: str, value: float, track: str = "main") -> None:
        return None


#: The module-wide disabled tracer every component defaults to.
NULL_TRACER = NullTracer()


@dataclass
class CounterSample:
    """One sampled counter value (queue depth, windows/s) on a track."""
    name: str
    track: str
    t: float                      # seconds since the tracer's t0
    value: float


class _SpanCtx:
    """Context manager closing one span and maintaining the parent stack."""
    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span

    def __enter__(self) -> Span:
        return self.span

    def __exit__(self, *exc) -> bool:
        t = self.tracer
        self.span.end = t._clock() - t._t0
        if t._stack and t._stack[-1] is self.span.id:
            t._stack.pop()
        return False


class Tracer:
    """Records spans with monotonic timestamps and parent/child links.

    Single-threaded by design (the streaming engine is a generator
    chain in one thread); the parent of a new span is whatever span is
    innermost open when it starts.
    """

    enabled = True

    def __init__(self):
        self._clock = time.perf_counter
        self._t0 = self._clock()
        self.spans: List[Span] = []
        self.counters: List[CounterSample] = []
        self._stack: List[int] = []          # open span ids (parent chain)

    # ------------------------------------------------------------ recording

    def span(self, name: str, cat: str = "pipeline", track: str = "main",
             **args) -> _SpanCtx:
        """Open a span; close it by exiting the returned context manager."""
        s = Span(id=len(self.spans), name=name, cat=cat, track=track,
                 start=self._clock() - self._t0,
                 parent=self._stack[-1] if self._stack else None,
                 args=args)
        self.spans.append(s)
        self._stack.append(s.id)
        return _SpanCtx(self, s)

    def instant(self, name: str, cat: str = "pipeline",
                track: str = "main", **args) -> Span:
        """A zero-duration marker (e.g. a rekey flip)."""
        t = self._clock() - self._t0
        s = Span(id=len(self.spans), name=name, cat=cat, track=track,
                 start=t, end=t,
                 parent=self._stack[-1] if self._stack else None,
                 args=args)
        self.spans.append(s)
        return s

    def counter(self, name: str, value: float, track: str = "main") -> None:
        """Sample a load curve (queue depth, windows/s) — rendered by
        Perfetto as a stacked area chart via Chrome "C" events."""
        self.counters.append(CounterSample(
            name=name, track=track, t=self._clock() - self._t0,
            value=float(value)))

    # -------------------------------------------------------------- queries

    def find(self, name: Optional[str] = None,
             cat: Optional[str] = None) -> List[Span]:
        """Spans filtered by exact name and/or category (tests)."""
        return [s for s in self.spans
                if (name is None or s.name == name)
                and (cat is None or s.cat == cat)]

    def children(self, span: Span) -> List[Span]:
        return [s for s in self.spans if s.parent == span.id]

    def __len__(self) -> int:
        return len(self.spans)

    # -------------------------------------------------------------- export

    def to_chrome(self) -> Dict[str, Any]:
        """The Chrome trace-event dict (``{"traceEvents": [...]}``).

        Complete ("X") events carry ``ts``/``dur`` in microseconds; each
        distinct track becomes a named tid via ``thread_name`` metadata
        events, so stages and workers render as separate lanes.
        """
        tids: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []
        for s in self.spans:
            tid = tids.setdefault(s.track, len(tids))
            ev: Dict[str, Any] = {
                "name": s.name, "cat": s.cat or "pipeline", "pid": 1,
                "tid": tid, "ts": round(s.start * 1e6, 3),
            }
            if s.end is not None and s.end > s.start:
                ev["ph"] = "X"
                ev["dur"] = round(s.dur * 1e6, 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"                 # instant scoped to its thread
            if s.args:
                ev["args"] = {k: (v if isinstance(v, (int, float, str,
                                                      bool, type(None)))
                                  else str(v)) for k, v in s.args.items()}
            events.append(ev)
        for c in self.counters:
            tid = tids.setdefault(c.track, len(tids))
            events.append({
                "name": c.name, "cat": "load", "ph": "C", "pid": 1,
                "tid": tid, "ts": round(c.t * 1e6, 3),
                "args": {"value": c.value},
            })
        meta = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
                 "args": {"name": "repro.pipeline"}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                  "args": {"name": track}}
                 for track, tid in sorted(tids.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Write (when ``path`` is given) and return the Chrome trace
        dict — load the file in ``chrome://tracing`` / Perfetto."""
        doc = self.to_chrome()
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
        return doc

    def timeline(self) -> str:
        """Human-readable indented timeline (ms offsets, span tree)."""
        depth: Dict[int, int] = {}
        buf = io.StringIO()
        for s in self.spans:
            d = 0 if s.parent is None else depth.get(s.parent, 0) + 1
            depth[s.id] = d
            attrs = " ".join(f"{k}={v}" for k, v in s.args.items())
            mark = f"[{s.start * 1e3:9.3f}ms +{s.dur * 1e3:8.3f}ms]"
            buf.write(f"{mark} {'  ' * d}{s.name} ({s.track})"
                      + (f" {attrs}" if attrs else "") + "\n")
        return buf.getvalue()
