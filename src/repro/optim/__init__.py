from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    make_optimizer,
    opt_state_shardings,
)
from repro.optim.schedules import warmup_cosine  # noqa: F401
