"""Optimizers with ZeRO-sharded state (pure functional, optax-style).

Three optimizers cover the assignment grid:

* ``adamw``     — fp32 m/v (the default for <100B-param archs);
* ``adafactor`` — factored second moments + no momentum; this is what makes
  the 1T-param kimi-k2 cell trainable at all on a 256-chip pod (DESIGN.md §4);
* ``sgdm``      — bf16 momentum, cheapest state.

ZeRO-1 state sharding: optimizer-state arrays get an *extra* sharded
dimension over the ``data`` (+``pod``) axes wherever divisible.  Under
GSPMD this turns the gradient all-reduce into reduce-scatter (into the
update) + all-gather (of the new params) automatically — the classic ZeRO
communication pattern, with no hand-written collectives.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import OptimizerConfig
from repro.dist.meshctx import MeshContext
from repro.optim.schedules import warmup_cosine

Params = Any


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], Params]
    update: Callable[[Params, Params, Params, jax.Array],
                     Tuple[Params, Params]]   # (grads, state, params, step)
    cfg: OptimizerConfig


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # Preserve grad dtype: casting the whole tree to fp32 here would double
    # grad memory (129 GB/chip for kimi-k2). Updates upcast per-leaf instead.
    return jax.tree.map(lambda g: (g * scale.astype(g.dtype)), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw(ocfg: OptimizerConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = _clip_by_global_norm(grads, ocfg.grad_clip)
        lr = warmup_cosine(step, peak_lr=ocfg.lr, warmup_steps=ocfg.warmup_steps)
        b1, b2 = ocfg.beta1, ocfg.beta2
        t = step.astype(jnp.float32) + 1.0
        corr1 = 1.0 - b1 ** t
        corr2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m2 / corr1
            vhat = v2 / corr2
            step_ = mhat / (jnp.sqrt(vhat) + ocfg.eps)
            step_ = step_ + ocfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
            return newp, m2, v2

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": m, "v": v}

    return Optimizer("adamw", init, update, ocfg)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------


def _adafactor(ocfg: OptimizerConfig) -> Optimizer:
    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def state_for(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(state_for, params)}

    def update(grads, state, params, step):
        grads, gnorm = _clip_by_global_norm(grads, ocfg.grad_clip)
        lr = warmup_cosine(step, peak_lr=ocfg.lr, warmup_steps=ocfg.warmup_steps)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** -0.8                      # Adafactor's schedule
        eps = 1e-30

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                rms = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps))
                news = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                rms = jnp.sqrt(v)
                news = {"v": v}
            step_ = g / jnp.maximum(rms, 1e-12)
            # relative step clipping (RMS-capped update)
            d = step_ / jnp.maximum(1.0, jnp.sqrt(
                jnp.mean(jnp.square(step_))))
            d = d + ocfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * d).astype(p.dtype)
            return newp, news

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        flat_p = tdef.flatten_up_to(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        newp = tdef.unflatten([o[0] for o in outs])
        news = tdef.unflatten([o[1] for o in outs])
        return newp, {"f": news}

    return Optimizer("adafactor", init, update, ocfg)


# ---------------------------------------------------------------------------
# SGD + momentum (bf16 state)
# ---------------------------------------------------------------------------


def _sgdm(ocfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)}

    def update(grads, state, params, step):
        grads, _ = _clip_by_global_norm(grads, ocfg.grad_clip)
        lr = warmup_cosine(step, peak_lr=ocfg.lr, warmup_steps=ocfg.warmup_steps)

        def upd(g, m, p):
            m2 = ocfg.beta1 * m.astype(jnp.float32) + g.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * m2).astype(p.dtype)
            return newp, m2.astype(jnp.bfloat16)

        out = jax.tree.map(upd, grads, state["mom"], params)
        newp = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        mom = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"mom": mom}

    return Optimizer("sgdm", init, update, ocfg)


_MAKERS = {"adamw": _adamw, "adafactor": _adafactor, "sgdm": _sgdm}


def make_optimizer(ocfg: OptimizerConfig) -> Optimizer:
    return _MAKERS[ocfg.name](ocfg)


# ---------------------------------------------------------------------------
# ZeRO sharding of optimizer state
# ---------------------------------------------------------------------------


def _zero_shard(spec: P, shape: Tuple[int, ...], ctx: MeshContext) -> P:
    """Add a ``data``(+``pod``) sharding to the first divisible unsharded dim."""
    axes = tuple(a for a in ("pod", "data") if a in ctx.mesh.axis_names)
    if not axes:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for prt in parts:
        for a in (prt if isinstance(prt, tuple) else (prt,)):
            if a:
                used.add(a)
    if any(a in used for a in axes):
        return spec  # already data-sharded somehow
    total = math.prod(ctx.mesh.shape[a] for a in axes)
    for i, (prt, dim) in enumerate(zip(parts, shape)):
        if prt is None and dim % total == 0:
            parts[i] = axes if len(axes) > 1 else axes[0]
            return P(*parts)
    # fall back: single-axis "data" only
    dsz = ctx.mesh.shape.get("data", 1)
    for i, (prt, dim) in enumerate(zip(parts, shape)):
        if prt is None and dim % dsz == 0:
            parts[i] = "data"
            return P(*parts)
    return spec


def opt_state_shardings(opt: Optimizer, params_abstract: Params,
                        param_shardings: Params, ctx: MeshContext) -> Params:
    """Shardings for opt.init(params): mirror param sharding + ZeRO axis."""
    state_abs = jax.eval_shape(opt.init, params_abstract)

    # Build a param-path -> (spec, shape) map, then apply it to state leaves
    # by matching the trailing tree structure (state trees mirror params).
    pspec = jax.tree.map(lambda s: s.spec, param_shardings)

    def assign(path, leaf):
        # state leaf path looks like ("m", <param path...>) or
        # ("f", <param path...>, "vr").  Walk the param tree with the middle
        # segment that exists in params.
        spec = _match_param_spec(path, pspec, leaf)
        if opt.cfg.zero_sharding:
            spec = _zero_shard(spec, leaf.shape, ctx)
        return NamedSharding(ctx.mesh, spec)

    return _tree_map_with_path(assign, state_abs)


def _tree_map_with_path(fn, tree):
    out = jax.tree_util.tree_map_with_path(lambda p, l: fn(p, l), tree)
    return out


def _match_param_spec(path, pspec_tree, leaf) -> P:
    """Find the param spec whose path is a sub-path of the state path."""
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(k.key)
        elif hasattr(k, "idx"):
            keys.append(k.idx)
    node = pspec_tree
    spec = None
    for k in keys:
        if isinstance(node, dict) and k in node:
            node = node[k]
        elif isinstance(node, (list, tuple)) and isinstance(k, int) and k < len(node):
            node = node[k]
        else:
            continue
        if isinstance(node, P):
            spec = node
    if spec is None:
        return P()
    last = keys[-1] if keys else None
    parts = list(spec)
    # adafactor factored states drop one param dim: vr drops the last,
    # vc drops the second-to-last.
    if last == "vr" and len(parts) >= 1:
        parts = parts[:-1]
    elif last == "vc" and len(parts) >= 2:
        parts = parts[:-2] + [parts[-1]]
    if len(parts) > leaf.ndim:
        parts = parts[:leaf.ndim]
    return P(*parts)
