"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int = 100_000, floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)
