"""Serving steps: batched prefill and single-token decode with KV caches.

``decode_step`` is the unit that the decode_* dry-run shapes lower: one new
token per sequence against a cache of ``seq_len`` — the memory-bandwidth-
bound regime of LM serving (see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.dist.meshctx import MeshContext
from repro.models import api as model_api

Params = Any


def make_prefill_step(run: RunConfig, ctx: MeshContext, *, max_seq: int):
    cfg = run.model

    def prefill_step(params, batch):
        logits, cache = model_api.prefill(cfg, params, batch, ctx,
                                          max_seq=max_seq)
        return logits, cache
    return prefill_step


def make_decode_step(run: RunConfig, ctx: MeshContext):
    cfg = run.model

    def decode_step(params, tokens, pos, cache):
        logits, new_cache = model_api.decode_step(cfg, params, tokens, pos,
                                                  cache, ctx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_cache
    return decode_step


def greedy_generate(run: RunConfig, ctx: MeshContext, params, prompt,
                    *, steps: int, max_seq: int):
    """Reference generation loop (prefill + N decode steps)."""
    cfg = run.model
    logits, cache = model_api.prefill(cfg, params, {"tokens": prompt}, ctx,
                                      max_seq=max_seq)
    B, S = prompt.shape
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    decode = jax.jit(make_decode_step(run, ctx),
                     donate_argnums=(3,))
    pos = jnp.int32(S)
    for i in range(steps - 1):
        tok, _, cache = decode(params, tok, pos, cache)
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
