from repro.train.steps import (  # noqa: F401
    make_train_step,
    make_eval_step,
    train_input_shardings,
)
