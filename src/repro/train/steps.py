"""Training / eval step factories.

``make_train_step`` returns a pure function
``(params, opt_state, batch, step) -> (params, opt_state, metrics)``
suitable for ``jax.jit`` with donated params/opt_state.  Gradient
accumulation (microbatching) is an inner ``lax.scan`` so the HLO stays
compact; the gradient all-reduce over the data axes and the ZeRO
reduce-scatter / all-gather pattern are produced by GSPMD from the
in/out shardings (see repro.optim.optimizers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.dist.meshctx import MeshContext
from repro.models import api as model_api
from repro.optim import make_optimizer

Params = Any


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(run: RunConfig, ctx: MeshContext):
    cfg = run.model
    opt = make_optimizer(run.optimizer)
    nmb = run.microbatches

    def loss_of(params, batch):
        loss, metrics = model_api.loss_fn(cfg, params, batch, ctx,
                                          remat=run.remat)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        if nmb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            mb = _split_microbatches(batch, nmb)

            def acc(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = lsum / nmb
            metrics = {"loss": loss}

        if run.optimizer.grad_compression == "fp16":
            # gradient compression trick: communicate / store accumulated
            # grads at half precision (visible in dry-run bytes).
            grads = jax.tree.map(lambda g: g.astype(jnp.float16)
                                 .astype(jnp.float32), grads)

        new_params, new_state = opt.update(grads, opt_state, params, step)
        metrics = dict(metrics)
        metrics["step"] = step.astype(jnp.float32)
        return new_params, new_state, metrics

    return train_step, opt


def make_eval_step(run: RunConfig, ctx: MeshContext):
    cfg = run.model

    def eval_step(params, batch):
        loss, metrics = model_api.loss_fn(cfg, params, batch, ctx,
                                          remat="none")
        return metrics
    return eval_step


def train_input_shardings(run: RunConfig, ctx: MeshContext,
                          batch_spec: Dict[str, jax.ShapeDtypeStruct]):
    """NamedShardings for the batch dict (batch dim over pod+data)."""
    def shard(sds):
        logical = ["batch"] + [None] * (len(sds.shape) - 1)
        if len(sds.shape) >= 2 and sds.shape[0] == 1:
            # long-context single-sequence shapes: shard the sequence instead
            logical = [None, "seq"] + [None] * (len(sds.shape) - 2)
        return ctx.sharding(logical, sds.shape)
    return jax.tree.map(shard, batch_spec)
