"""Trainer: the supervised loop tying everything together.

data (sealed SecureStreams source) -> train_step (jit, donated) ->
sealed checkpoints every N steps -> failure recovery (checkpoint-restart)
-> straggler detection on step times.  This is the end-to-end driver used
by examples/secure_lm_train.py and the integration tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.attest.directory import KeyDirectory
from repro.attest.measure import IO_ENDPOINT
from repro.ckpt import checkpoint as ckpt
from repro.configs.base import RunConfig
from repro.core.enclave import ingress, egress
from repro.dist.meshctx import MeshContext
from repro.ft.failures import FailureInjector
from repro.ft.straggler import StragglerDetector
from repro.models import api as model_api
from repro.optim import make_optimizer
from repro.train.steps import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro-ckpt"
    sealed_ckpt: bool = True
    sealed_data: bool = True
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, run: RunConfig, ctx: MeshContext,
                 data_fn: Callable[[int], Dict[str, np.ndarray]],
                 tcfg: TrainerConfig = TrainerConfig(),
                 injector: Optional[FailureInjector] = None):
        self.run = run
        self.ctx = ctx
        self.tcfg = tcfg
        self.data_fn = data_fn           # step -> batch dict (deterministic!)
        self.injector = injector
        self.detector = StragglerDetector()
        self.history: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []

        step_fn, self.opt = make_train_step(run, ctx)
        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        # Attested data channel: the source and the trainer handshake via
        # the KeyDirectory; the session key (not a derived constant) seals
        # every batch.  One directory per trainer = one trust domain; a
        # restart reuses it, so replayed chunks re-open under the same key.
        self.directory = KeyDirectory(seed=tcfg.seed)
        self.directory.enroll("io/data-source", IO_ENDPOINT, allow=True)
        self.directory.enroll("trainer", IO_ENDPOINT, allow=True)
        self._data_key = self.directory.establish(
            "train-data", "io/data-source", "trainer", stage_id=0)

        self.params = model_api.init_params(run.model, jax.random.key(run.seed))
        self.opt_state = self.opt.init(self.params)
        self.step = 0
        # Step-0 snapshot: restore() must rewind to a state-consistent
        # point even when NO checkpoint exists yet (a failure before the
        # first save).  Without this, a restart would replay steps on top
        # of the failed attempt's partially-advanced params/opt_state —
        # double-folding the optimizer trajectory.
        # (real copies: the jitted step donates params/opt_state buffers,
        # so aliasing the live tree would snapshot invalidated memory)
        _copy = lambda x: jnp.array(x) if isinstance(x, jax.Array) else x  # noqa: E731
        self._init_params = jax.tree_util.tree_map(_copy, self.params)
        self._init_opt_state = jax.tree_util.tree_map(_copy, self.opt_state)

    # ------------------------------------------------------------ data path

    def _sealed_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """Fetch the step's batch through the secure ingest path."""
        raw = self.data_fn(step)
        if not self.tcfg.sealed_data:
            return {k: jnp.asarray(v) for k, v in raw.items()}
        out = {}
        for i, (k, v) in enumerate(sorted(raw.items())):
            chunk = ingress("encrypted", self._data_key,
                            step * 16 + i, jnp.asarray(v))
            x, ok = egress("encrypted", self._data_key, chunk)
            if not bool(ok):
                raise RuntimeError(f"data chunk MAC failure at step {step}")
            out[k] = x
        return out

    # ------------------------------------------------------------- recovery

    def save(self) -> None:
        ckpt.save(self.tcfg.ckpt_dir, self.step, self.params, self.opt_state,
                  sealed=self.tcfg.sealed_ckpt, seed=self.tcfg.seed,
                  extra={"arch": self.run.model.arch_id})

    def restore(self) -> int:
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            # no checkpoint yet: rewind to the step-0 snapshot — the
            # failed attempt's partial progress must not leak into the
            # replay (recovered output == uninterrupted output, exactly)
            _copy = lambda x: jnp.array(x) if isinstance(x, jax.Array) \
                else x  # noqa: E731
            self.params = jax.tree_util.tree_map(_copy, self._init_params)
            self.opt_state = jax.tree_util.tree_map(
                _copy, self._init_opt_state)
            self.step = 0
            return 0
        step, params, opt_state = ckpt.restore(
            self.tcfg.ckpt_dir, last, seed=self.tcfg.seed,
            params_like=self.params, opt_like=self.opt_state)
        self.params, self.opt_state = params, opt_state
        self.step = step
        return step

    # ----------------------------------------------------------------- loop

    def run_steps(self, start: int, end: int) -> int:
        for s in range(start, end):
            if self.injector is not None:
                self.injector.maybe_fail(s)
            t0 = time.perf_counter()
            batch = self._sealed_batch(s)
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch, jnp.int32(s))
            loss = float(metrics.get("loss", jnp.nan))
            dt = time.perf_counter() - t0
            if self.detector.observe(dt):
                self.straggler_steps.append(s)
            self.step = s + 1
            if self.step % self.tcfg.log_every == 0:
                self.history.append({"step": self.step, "loss": loss,
                                     "sec_per_step": dt})
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
        return self.step

    def train(self) -> Dict[str, Any]:
        from repro.ft.failures import run_with_recovery
        report = run_with_recovery(
            total_steps=self.tcfg.total_steps,
            run_steps=self.run_steps,
            restore=self.restore,
        )
        self.save()
        return {
            "final_step": report.final_step,
            "restarts": report.restarts,
            "replayed_steps": report.replayed_steps,
            "history": self.history,
            "stragglers": self.straggler_steps,
        }
