"""Deterministic stand-in for `hypothesis` on containers that lack it.

Implements just the surface these tests use — ``given``, ``settings``,
``strategies.integers/sampled_from/booleans`` — by drawing
``max_examples`` pseudo-random example tuples from a fixed seed.  No
shrinking, no database; failures report the drawn example in the assert
traceback.  If real hypothesis is installed the test modules import it
instead, so this file is only ever loaded as a fallback.
"""
from __future__ import annotations

import random
from types import SimpleNamespace
from typing import Any, Callable, List


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self.draw = draw


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(lo, hi))


def _sampled_from(items) -> _Strategy:
    items = list(items)
    return _Strategy(lambda r: r.choice(items))


def _booleans() -> _Strategy:
    return _Strategy(lambda r: r.choice([False, True]))


strategies = SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                             booleans=_booleans)


class settings:
    """Decorator-compatible subset: only max_examples is honored."""

    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_max_examples = self.max_examples
        return fn


def given(*strats: _Strategy):
    def deco(fn):
        # deliberately NOT functools.wraps: the wrapper must expose a
        # zero-arg signature so pytest doesn't treat the strategy params
        # as fixtures.
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", 20)
            rng = random.Random(0)
            for _ in range(n):
                fn(*[s.draw(rng) for s in strats])

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
