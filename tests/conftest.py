"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 CPU
device; only launch/dryrun.py forces the 512-device host platform."""
import jax
import pytest

from repro.dist.meshctx import local_mesh_context


@pytest.fixture(scope="session")
def ctx():
    return local_mesh_context()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
