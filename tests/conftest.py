"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 CPU
device; only launch/dryrun.py forces the 512-device host platform."""
import jax
import pytest

from repro.dist.meshctx import local_mesh_context


@pytest.fixture(scope="session")
def ctx():
    return local_mesh_context()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


@pytest.fixture(autouse=True, scope="module")
def _fresh_aead_fastpath_stats():
    """Zero the AEAD compile-cache STATS at each module boundary so
    cache-hit assertions (test_aead_fastpath) are order-independent —
    any module may warm the cache with arbitrary shapes before them.
    Compiled programs are kept (stats-only reset): dropping them would
    re-pay ~2 s/shape compiles in every module; tests that need a cold
    cache call aead.reset_fastpath_cache() themselves."""
    from repro.crypto import aead
    aead.reset_fastpath_stats()
    yield
