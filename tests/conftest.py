"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 CPU
device; only launch/dryrun.py forces the 512-device host platform."""
import jax
import pytest

from repro.dist.meshctx import local_mesh_context


@pytest.fixture(scope="session")
def ctx():
    return local_mesh_context()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


@pytest.fixture(autouse=True, scope="module")
def _fresh_obs_state():
    """Reset process-global observability state at each module boundary
    so counter/histogram/audit assertions are order-independent.

    * ``obs.metrics.REGISTRY.reset()`` zeroes every registered
      instrument — including the AEAD compile-cache stats the previous
      version of this fixture reset (any module may warm the cache with
      arbitrary shapes) and the host-sync/dispatch counters the window
      engine asserts on.  Instruments stay REGISTERED: hot-path
      references (module-level ``_FP_HITS`` etc.) remain valid, and
      compiled programs are kept — dropping them would re-pay ~2 s/shape
      compiles per module; tests that need a cold cache call
      ``aead.reset_fastpath_cache()`` themselves.
    * ``dist.pipeline_parallel._DEFAULT_DIRS`` caches KeyDirectories
      across tests; their owned AuditLogs would otherwise accumulate
      events across modules and flip exact-count assertions with test
      ordering.
    """
    from repro.dist import pipeline_parallel as _pp
    from repro.obs.metrics import REGISTRY
    REGISTRY.reset()
    for d in _pp._DEFAULT_DIRS.values():
        d.audit.clear()
    yield
