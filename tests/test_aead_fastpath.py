"""Batched AEAD fast path (ISSUE 2): seal_many/open_many parity with the
scalar path on RFC 7539-derived vectors, Pallas-vs-jnp oracle checks,
batched tamper detection, the shape-keyed compile cache, and the
single-collective secure_exchange."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attest.directory import ephemeral_edge_key
from repro.crypto import aead, chacha20, cwmac

rng = np.random.default_rng(7)

# RFC 7539 §2.3.2 test-vector key/nonce (word-little-endian, as in
# test_kernels.test_chacha20_rfc7539_block)
RFC_KEY = jnp.asarray(np.array(
    [0x03020100, 0x07060504, 0x0b0a0908, 0x0f0e0d0c,
     0x13121110, 0x17161514, 0x1b1a1918, 0x1f1e1d1c], dtype=np.uint32))
RFC_NONCE = jnp.asarray(np.array([0x09000000, 0x4a000000, 0x00000000],
                                 dtype=np.uint32))


def _u32(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** 32, shape, dtype=np.uint32))


# ------------------------------------------------------------ scalar fusion


def test_scalar_seal_single_pass_matches_two_pass_construction():
    """The fused seal (one chacha20 pass over counters 0..N) must equal the
    legacy construction: encrypt at counter0=1 + MAC keys from block 0."""
    pt = _u32(100, seed=1)
    ct, tag = aead.seal(RFC_KEY, RFC_NONCE, pt)
    ct_ref = chacha20.encrypt_words(RFC_KEY, RFC_NONCE, pt, counter0=1)
    r1, s1, r2, s2 = aead.derive_mac_keys(RFC_KEY, RFC_NONCE)
    tag_ref = cwmac.mac2(ct_ref, r1, s1, r2, s2)
    assert bool((ct == ct_ref).all()) and bool((tag == tag_ref).all())
    pt2, ok = aead.open_(RFC_KEY, RFC_NONCE, ct, tag)
    assert bool(ok) and bool((pt2 == pt).all())


def test_scalar_seal_keystream_is_rfc7539_block1():
    """Sealing zeros exposes the keystream: words 0..15 must be the RFC
    7539 §2.3.2 counter-1 block."""
    ct, _ = aead.seal(RFC_KEY, RFC_NONCE, jnp.zeros((16,), jnp.uint32))
    expected = np.array([0xe4e7f110, 0x15593bd1, 0x1fdd0f50, 0xc47120a3,
                         0xc7f4d1c7, 0x0368c033, 0x9aaa2204, 0x4e6cd4c3,
                         0x466482d2, 0x09aa9f07, 0x05d7c214, 0xa2028bd9,
                         0xd19c12b5, 0xb94e16de, 0xe883d0cb, 0x4e3c50a2],
                        dtype=np.uint32)
    assert np.array_equal(np.asarray(ct), expected)


# ------------------------------------------------------- batched vs scalar


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
@pytest.mark.parametrize("B,n", [(1, 16), (4, 100), (9, 33)])
def test_seal_many_matches_vmap_seal(backend, B, n):
    """seal_many == vmap(seal) item-wise, RFC key among the batch nonces."""
    nonces = _u32((B, 3), seed=2).at[0].set(RFC_NONCE)
    words = _u32((B, n), seed=3)
    ct_b, tag_b = aead.seal_many(RFC_KEY, nonces, words, backend=backend)
    ct_v, tag_v = jax.vmap(aead.seal, in_axes=(None, 0, 0))(
        RFC_KEY, nonces, words)
    assert bool((ct_b == ct_v).all()) and bool((tag_b == tag_v).all())
    pt, ok = aead.open_many(RFC_KEY, nonces, ct_b, tag_b, backend=backend)
    assert bool(ok.all()) and bool((pt == words).all())


def test_seal_many_per_item_keys():
    B, n = 5, 40
    keys = _u32((B, 8), seed=4)
    nonces = _u32((B, 3), seed=5)
    words = _u32((B, n), seed=6)
    ct_b, tag_b = aead.seal_many(keys, nonces, words)
    ct_v, tag_v = jax.vmap(aead.seal)(keys, nonces, words)
    assert bool((ct_b == ct_v).all()) and bool((tag_b == tag_v).all())


def test_seal_many_backends_agree():
    """Pallas kernel path vs pure-jnp oracle on the same batch."""
    B, n = 4, 130
    nonces, words = _u32((B, 3), seed=8), _u32((B, n), seed=9)
    out_p = aead.seal_many(RFC_KEY, nonces, words, backend="pallas")
    out_j = aead.seal_many(RFC_KEY, nonces, words, backend="jnp")
    for a, b in zip(out_p, out_j):
        assert bool((a == b).all())


def test_seal_many_shape_validation():
    with pytest.raises(ValueError):
        aead.seal_many(RFC_KEY, _u32((2, 3)), _u32(16))
    with pytest.raises(ValueError):
        aead.seal_many(RFC_KEY, _u32((3, 3)), _u32((2, 16)))
    with pytest.raises(ValueError):
        aead.seal_many(_u32((4, 8)), _u32((2, 3)), _u32((2, 16)))
    with pytest.raises(ValueError):  # non-u32 payloads must be bitcast first
        aead.seal_many(RFC_KEY, _u32((2, 3)),
                       jnp.zeros((2, 16), jnp.int32))
    with pytest.raises(ValueError):  # typo'd backend must not fall through
        aead.seal_many(RFC_KEY, _u32((2, 3)), _u32((2, 16)),
                       backend="pallsa")


# ----------------------------------------------------------- cwmac batched


def test_cwmac_batch_matches_scalar_and_host_reference():
    B, n = 6, 77
    words = np.random.default_rng(10).integers(0, 2 ** 32, (B, n),
                                               dtype=np.uint32)
    r = np.random.default_rng(11).integers(1, 2 ** 31 - 1, B,
                                           dtype=np.uint32)
    s = np.random.default_rng(12).integers(0, 2 ** 31 - 1, B,
                                           dtype=np.uint32)
    got = cwmac.mac_batch(jnp.asarray(words), jnp.asarray(r), jnp.asarray(s))
    for b in range(B):
        want = cwmac.mac_reference(words[b], int(r[b]), int(s[b]))
        assert int(got[b]) == want
        assert int(got[b]) == int(cwmac.mac(jnp.asarray(words[b]),
                                            jnp.uint32(r[b]),
                                            jnp.uint32(s[b])))


@pytest.mark.parametrize("B,n", [(2, 50), (5, 1024), (3, 17)])
def test_cwmac_pallas_batch_matches_jnp_oracle(B, n):
    from repro.kernels.cwmac import ops as mac_ops
    words = _u32((B, n), seed=13)
    r1, s1 = _u32(B, 14) & np.uint32(0x7FFFFFFE), _u32(B, 15) & np.uint32(
        0x7FFFFFFE)
    r2, s2 = _u32(B, 16) & np.uint32(0x7FFFFFFE), _u32(B, 17) & np.uint32(
        0x7FFFFFFE)
    t_kernel = mac_ops.mac2_batch(words, r1, s1, r2, s2)
    t_jnp = cwmac.mac2_batch(words, r1, s1, r2, s2)
    assert bool((t_kernel == t_jnp).all())


# ------------------------------------------------------------------ tamper


def test_open_many_tamper_detection_is_per_item():
    B, n = 6, 64
    nonces, words = _u32((B, 3), seed=18), _u32((B, n), seed=19)
    ct, tags = aead.seal_many(RFC_KEY, nonces, words)
    bad_ct = ct.at[2, 10].set(ct[2, 10] ^ np.uint32(4))
    bad_tags = tags.at[4, 0].set(tags[4, 0] ^ np.uint32(1))
    _, ok = aead.open_many(RFC_KEY, nonces, bad_ct, tags)
    assert [bool(v) for v in ok] == [True, True, False, True, True, True]
    _, ok2 = aead.open_many(RFC_KEY, nonces, ct, bad_tags)
    assert [bool(v) for v in ok2] == [True, True, True, True, False, True]
    # wrong nonce on one item
    _, ok3 = aead.open_many(RFC_KEY, nonces.at[1, 1].add(np.uint32(1)),
                            ct, tags)
    assert not bool(ok3[1]) and bool(ok3[0])


# ----------------------------------------------------------- compile cache


def test_compile_cache_hits_on_round_two():
    """Round 1 of a fresh (B, n) shape compiles; round 2 must be a pure
    cache hit (no new program)."""
    aead.reset_fastpath_cache()
    nonces, words = _u32((3, 3), seed=20), _u32((3, 48), seed=21)
    aead.seal_many(RFC_KEY, nonces, words)
    s1 = aead.fastpath_stats()
    assert s1["compiles"] == 1 and s1["hits"] == 0
    aead.seal_many(RFC_KEY, nonces, words)
    s2 = aead.fastpath_stats()
    assert s2["compiles"] == 1 and s2["hits"] == 1
    # a different shape is a new program ...
    aead.seal_many(RFC_KEY, nonces, _u32((3, 49), seed=22))
    assert aead.fastpath_stats()["compiles"] == 2
    # ... and open has its own entry, also hit on round 2
    ct, tags = aead.seal_many(RFC_KEY, nonces, words)
    aead.open_many(RFC_KEY, nonces, ct, tags)
    c = aead.fastpath_stats()["compiles"]
    aead.open_many(RFC_KEY, nonces, ct, tags)
    assert aead.fastpath_stats()["compiles"] == c


# ------------------------------------------------- batch framing + channel


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "uint32", "int32"])
def test_tensor_batch_framing_matches_scalar(dtype):
    shape = (4, 5, 3)
    if dtype in ("float32", "bfloat16"):
        x = jax.random.normal(jax.random.key(0), shape).astype(dtype)
    else:
        x = jax.random.randint(jax.random.key(0), shape, 0, 999).astype(dtype)
    wb, meta = aead.tensor_to_words_batch(x)
    for b in range(shape[0]):
        ws, _ = aead.tensor_to_words(x[b])
        assert bool((wb[b] == ws).all())
    x2 = aead.words_to_tensor_batch(wb, meta)
    assert x2.dtype == x.dtype and bool((x2 == x).all())


def test_protect_many_roundtrip_and_cross_key_rejection():
    from repro.core.secure_channel import protect_many, unprotect_many
    keys = [ephemeral_edge_key(f"edge{i}", seed=3, stage_id=i)
            for i in range(3)]
    steps = [10, 11, 12]
    xs = jax.random.normal(jax.random.key(1), (3, 4, 6), jnp.bfloat16)
    cts, tags, meta = protect_many(keys, steps, xs)
    ys, ok = unprotect_many(keys, steps, cts, tags, meta)
    assert bool(ok.all()) and bool((ys == xs).all())
    # swapping two edge keys must fail exactly those items
    _, ok2 = unprotect_many([keys[1], keys[0], keys[2]], steps, cts, tags,
                            meta)
    assert [bool(v) for v in ok2] == [False, False, True]


# --------------------------------------------- single-collective exchange


def test_secure_exchange_issues_one_collective_per_round():
    from repro.dist import collectives
    mesh = jax.make_mesh((1,), ("model",))
    x = jax.random.normal(jax.random.key(3), (1, 1, 16, 4), jnp.float32)
    key = ephemeral_edge_key("shuffle", seed=0)
    c0 = collectives.exchange_call_count()
    y, ok = collectives.secure_exchange(x, mesh, "model", key=key, step=5)
    assert collectives.exchange_call_count() - c0 == 1
    assert bool(ok.all())
    assert float(jnp.abs(y - jnp.swapaxes(x, 0, 1)).max()) == 0.0


def test_sealed_ppermute_packed_payload_roundtrip():
    """ct + tag ride one packed ppermute payload; roundtrip is exact."""
    from jax.sharding import PartitionSpec as P
    from repro.core.secure_channel import sealed_ppermute
    from repro.dist.compat import shard_map
    mesh = jax.make_mesh((1,), ("stage",))
    key = ephemeral_edge_key("pp-edge", seed=2, stage_id=1)
    x = jnp.arange(1 * 32, dtype=jnp.uint32).reshape(1, 32)

    def body(xb):  # local (1, 32)
        y, ok = sealed_ppermute(key, 3, xb[0], "stage", [(0, 0)])
        return y[None], ok.reshape(1)

    y, ok = shard_map(body, mesh=mesh, in_specs=P("stage"),
                      out_specs=(P("stage"), P("stage")),
                      check_vma=False)(x)
    assert bool(ok.all()) and bool((y == x).all())


def test_route_nonce_cache_reuses_host_arrays():
    from repro.dist.collectives import _route_nonces
    a = _route_nonces(4, 9)
    b = _route_nonces(4, 9)
    assert a is b                      # cached jnp array, not rebuilt
    c = _route_nonces(4, 10)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # counter layout unchanged: (step*W + src)*W + dst, little word first
    W, step = 4, 9
    flat = np.asarray(a).reshape(W, W, 3)
    for src in range(W):
        for dst in range(W):
            cnt = (step * W + src) * W + dst
            assert flat[src, dst, 1] == cnt & 0xFFFFFFFF
            assert flat[src, dst, 0] == 0
