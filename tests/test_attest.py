"""repro.attest: measurements, quotes, handshake, KeyDirectory lifecycle
(epoch rekeying, revocation), and the rewired sealed paths — including the
8-stage rekey+revocation parity run and the derive_stage_key grep gate."""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attest.directory import (KeyDirectory, KeyDirectoryError,
                                    NoSessionError, RevokedWorkerError,
                                    ephemeral_edge_key)
from repro.attest.handshake import (HandshakeEnd, HandshakeError,
                                    HandshakeMessage, bind_share)
from repro.attest.measure import IO_ENDPOINT, measure_fn, measure_stage
from repro.attest.quote import QuoteError, QuotePolicy
from repro.attest.rotation import hkdf_sha256, ratchet_key
from repro.crypto.keys import (NONCE_COUNTER_MAX, NonceExhaustedError,
                               StageKey)


def _directory(seed=0, **kw):
    d = KeyDirectory(seed=seed, **kw)
    d.enroll("a", IO_ENDPOINT, allow=True)
    d.enroll("b", IO_ENDPOINT, allow=True)
    return d


# ---------------------------------------------------------- measurements


def test_measurements_deterministic_and_sensitive():
    m1 = measure_stage(op="scale", const=2.0)
    assert m1 == measure_stage(op="scale", const=2.0)
    assert m1 != measure_stage(op="scale", const=3.0)      # const matters
    assert m1 != measure_stage(op="add", const=2.0)        # op matters
    assert m1 != measure_stage(op="scale", const=2.0, sgx=False)

    f1 = lambda x: x * 2.0
    f2 = lambda x: x * 2.0
    f3 = lambda x: x * 3.0
    assert measure_fn(f1) == measure_fn(f2)    # same bytecode, same identity
    assert measure_fn(f1) != measure_fn(f3)    # tampered body measured

    # nested code objects measure recursively (repr would embed addresses)
    g1 = lambda x: (lambda y: y + 1.0)(x)
    g2 = lambda x: (lambda y: y + 1.0)(x)
    g3 = lambda x: (lambda y: y + 2.0)(x)
    assert measure_fn(g1) == measure_fn(g2)
    assert measure_fn(g1) != measure_fn(g3)    # inner-body tamper seen

    # closure captures are part of the identity: same bytecode, different
    # captured value -> different behavior -> different measurement
    def make(s):
        return lambda x: x * s
    assert measure_fn(make(2.0)) == measure_fn(make(2.0))
    assert measure_fn(make(2.0)) != measure_fn(make(3.0))
    # ...and so are defaults
    d1 = lambda x, s=2.0: x * s
    d2 = lambda x, s=3.0: x * s
    assert measure_fn(d1) != measure_fn(d2)
    # large captured arrays hash full contents — repr elides interior
    # elements, which would let a mid-array tamper keep verifying
    w1, w2 = np.zeros(2000, np.float32), np.zeros(2000, np.float32)
    w2[1000] = 42.0
    assert measure_fn(make(w1)) == measure_fn(make(w1.copy()))
    assert measure_fn(make(w1)) != measure_fn(make(w2))


# ----------------------------------------------------------------- quotes


def test_quote_verify_and_rejections():
    d = _directory()
    q = d.quote_for("a", b"ctx")
    d.verify(q, expect_report_data=b"ctx")

    # forged signature
    import dataclasses
    bad = dataclasses.replace(q, signature=b"\x00" * 32)
    with pytest.raises(QuoteError, match="bad-signature"):
        d.verify(bad)
    # binding mismatch (quote replayed into another session)
    with pytest.raises(QuoteError, match="report-data-mismatch"):
        d.verify(q, expect_report_data=b"other")
    # measurement not allowlisted
    d.enroll("rogue", b"\xde\xad" * 16)           # enrolled, NOT allowed
    with pytest.raises(QuoteError, match="measurement-not-allowed"):
        d.verify(d.quote_for("rogue"))
    assert not d.is_admitted("rogue")
    # stale: age policy over the logical clock
    ds = _directory(seed=1, policy=None)
    ds.policy.max_quote_age = 2
    ds.enroll("c", IO_ENDPOINT, allow=True)
    q = ds.quote_for("c")
    ds.tick(3)
    with pytest.raises(QuoteError, match="stale"):
        ds.verify(q)
    assert ds.is_admitted("c")                    # a FRESH quote still passes
    # revoked
    d.revoke("b")
    with pytest.raises(RevokedWorkerError):
        d.verify(d.quote_for("b"))
    assert not d.is_admitted("b") and d.is_admitted("a")


def test_enrollment_is_immutable():
    d = _directory()
    with pytest.raises(KeyDirectoryError, match="immutable"):
        d.enroll("a", b"\x01" * 32)
    d.enroll("a", IO_ENDPOINT)                    # same measurement is fine


# -------------------------------------------------------------- handshake


def test_handshake_agrees_and_binds_transcript():
    d = _directory()
    k = d.establish("e", "a", "b", stage_id=4)
    assert isinstance(k, StageKey) and k.stage_id == 4
    assert k.key.shape == (8,) and k.key.dtype == np.uint32
    # the stored session key is what both ends derived
    assert np.array_equal(d.edge_key("e").key, k.key)
    # distinct edges (different contexts) get distinct keys
    k2 = d.establish("e2", "a", "b")
    assert not np.array_equal(k.key, k2.key)
    # re-establishing replaces the session with a fresh key
    k3 = d.establish("e", "a", "b", stage_id=4)
    assert not np.array_equal(k.key, k3.key)


def test_handshake_rejects_mitm_and_unverified_peer():
    d = _directory()
    ends = {}
    for wid in ("a", "b"):
        ends[wid] = HandshakeEnd(
            quote_fn=lambda rd, w=wid: d.quote_for(w, rd),
            verify_fn=lambda q, rd: d.verify(q, expect_report_data=rd),
            secret=d._rng.randrange(2, 1 << 255), context=b"ctx")
    fa, fb = ends["a"].flight(), ends["b"].flight()
    # substituted DH share: the quote no longer binds -> rejected
    evil = HandshakeMessage(pub=pow(2, 12345, int(1e30) + 57), quote=fb.quote)
    with pytest.raises((QuoteError, HandshakeError)):
        ends["a"].derive(fa, evil)
    # a revoked peer's fresh quote is rejected mid-handshake
    d.revoke("b")
    with pytest.raises(RevokedWorkerError):
        ends["a"].derive(fa, HandshakeMessage(
            pub=fb.pub, quote=d._qk.quote("b", IO_ENDPOINT,
                                          bind_share(b"ctx", fb.pub),
                                          now=d.clock)))
    # both honest flights agree when admitted
    d2 = _directory(seed=2)
    k = d2.establish("e", "a", "b")
    assert k.key.shape == (8,)


def test_establish_requires_admissible_endpoints():
    d = _directory()
    d.revoke("b")
    with pytest.raises(RevokedWorkerError):
        d.establish("e", "a", "b")
    with pytest.raises(KeyDirectoryError):
        d.establish("e", "a", "a")               # two distinct endpoints


# ------------------------------------------------ epochs, counters, nonce


def test_advance_epoch_ratchets_and_resets_counters():
    d = _directory()
    d.establish("e", "a", "b")
    k0 = d.edge_key("e")
    assert d.next_counter("e") == 0 and d.next_counter("e") == 1
    assert d.session("e").chunks == 2

    assert d.advance_epoch() == 1
    k1 = d.edge_key("e")
    assert not np.array_equal(k0.key, k1.key)          # ratcheted
    assert d.session("e").chunks == 0                  # counter cleared
    assert d.next_counter("e") == 0
    # the drained epoch stays openable...
    assert np.array_equal(d.edge_key("e", epoch=0).key, k0.key)
    # ...and the ratchet is the public one-way function
    expect = ratchet_key(k0, epoch=1, transcript=d.session("e").transcript)
    assert np.array_equal(k1.key, expect.key)


def test_epoch_history_is_bounded():
    d = _directory(epoch_history=2)
    d.establish("e", "a", "b")
    k0 = d.edge_key("e")
    d.advance_epoch()
    d.advance_epoch()
    with pytest.raises(NoSessionError, match="drained past history"):
        d.edge_key("e", epoch=0)
    assert d.edge_key("e", epoch=1) is not None
    assert not np.array_equal(d.edge_key("e").key, k0.key)


def test_nonce_exhaustion_guard_and_rotation_clears_it():
    k = ephemeral_edge_key("t", seed=0)
    assert k.nonce(NONCE_COUNTER_MAX) is not None      # last valid counter
    with pytest.raises(NonceExhaustedError):
        k.nonce(NONCE_COUNTER_MAX + 1)
    with pytest.raises(NonceExhaustedError):
        k.nonce(-1)
    # the rotation path clears an almost-exhausted per-edge counter
    d = _directory()
    d.establish("e", "a", "b")
    d.session("e").chunks = NONCE_COUNTER_MAX          # one step from wrap
    d.edge_key("e").nonce(d.next_counter("e"))         # still sealable
    with pytest.raises(NonceExhaustedError):
        d.edge_key("e").nonce(d.next_counter("e"))     # would wrap
    d.advance_epoch()
    assert d.session("e").chunks == 0                  # rotation resets
    d.edge_key("e").nonce(d.next_counter("e"))         # sealable again


def test_hkdf_sha256_expands():
    out = hkdf_sha256(b"ikm", salt=b"salt", info=b"info", length=64)
    assert len(out) == 64
    assert out[:32] == hkdf_sha256(b"ikm", salt=b"salt", info=b"info")
    assert out != hkdf_sha256(b"ikm2", salt=b"salt", info=b"info", length=64)


# ------------------------------------------------------------- revocation


def test_revoke_drops_sessions_and_blocks_rehandshake():
    d = _directory()
    d.enroll("c", IO_ENDPOINT, allow=True)
    d.establish("ab", "a", "b")
    d.establish("ac", "a", "c")
    dropped = d.revoke("b")
    assert dropped == ["ab"]
    assert not d.has_session("ab") and d.has_session("ac")
    # a typo'd id must fail loudly, not silently "revoke" nobody
    with pytest.raises(KeyDirectoryError, match="unknown worker"):
        d.revoke("stage/w1")
    with pytest.raises(RevokedWorkerError):
        d.reestablish("ab", "a", "b")
    # survivors re-handshake fine
    d.reestablish("ab2", "a", "c")


def test_run_with_recovery_revokes_and_reestablishes():
    from repro.ft.failures import FailureInjector, run_with_recovery
    d = _directory()
    d.enroll("c", IO_ENDPOINT, allow=True)
    d.establish("stream", "a", "b")
    inj = FailureInjector(schedule={3: "revoked:b"})
    rehandshakes = []

    def reestablish(directory):
        # re-handshake on the surviving set (c replaces b)
        rehandshakes.append(directory.establish("stream", "a", "c"))

    state = {"step": 0}

    def run_steps(start, end):
        for s in range(start, end):
            inj.maybe_fail(s)
            d.edge_key("stream")       # the stream needs a live session
            state["step"] = s + 1
        return state["step"]

    rep = run_with_recovery(total_steps=6, run_steps=run_steps,
                            restore=lambda: state["step"],
                            directory=d, reestablish=reestablish)
    assert rep.final_step == 6
    assert rep.revoked_workers == ["b"]
    assert "b" in d.policy.revoked and len(rehandshakes) == 1
    assert d.session("stream").right == "c"


# --------------------------------------------- pipeline integration (e2e)


def _stage8():
    from repro.core.pipeline import Stage
    return [Stage(f"s{i}", op="scale_f32", const=1.0 + 0.125 * i,
                  workers=2 if i % 3 == 0 else 1) for i in range(8)]


def test_8stage_rekey_and_revocation_bit_identical():
    """Acceptance run: 8 sealed stages, rekey_every_n forcing >= 2 epoch
    flips, one mid-stream revocation — bit-identical output to a
    static-key (no rekey, no revocation) run."""
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline

    src = [jnp.asarray(np.random.default_rng(i).standard_normal(
        (64,)).astype(np.float32)) for i in range(9)]

    p_static = Pipeline(_stage8(), SecureStreamConfig(mode="encrypted"))
    got_static = []
    p_static.run(iter(src), on_result=lambda r: got_static.append(
        np.asarray(r)))
    assert p_static.directory.epoch == 0

    p = Pipeline(_stage8(), SecureStreamConfig(mode="encrypted"))

    def source():
        for i, c in enumerate(src):
            if i == 4:   # mid-stream: evict one worker of stage s3
                p.directory.revoke(Pipeline.worker_id("s3", 1))
            yield c

    got = []
    p.run(source(), on_result=lambda r: got.append(np.asarray(r)),
          rekey_every_n=3)
    assert p.directory.epoch >= 2                      # >= 2 epoch flips
    assert not p.directory.is_admitted(Pipeline.worker_id("s3", 1))
    assert len(got) == len(got_static) == len(src)
    for a, b in zip(got, got_static):
        assert np.array_equal(a, b)                    # bit-identical
    # the revoked worker stopped receiving chunks after eviction
    pw = p.report()["s3"]["per_worker"]
    assert len(pw) == 2 and pw[1] < pw[0]

    # ---- the WINDOW-BATCHED engine must agree bit-for-bit too: with
    # epoch_history covering the deeper windowed in-flight lag, whole
    # windows straddle the rekey flips (window 16 chunks vs rekey
    # every 3), so every batched open resolves per-row ingress epochs.
    pb = Pipeline(_stage8(), SecureStreamConfig(mode="encrypted"),
                  directory=KeyDirectory(seed=0, epoch_history=64),
                  window_chunks=8)

    def source_b():
        for i, c in enumerate(src):
            if i == 4:
                pb.directory.revoke(Pipeline.worker_id("s3", 1))
            yield c

    got_b = []
    pb.run(source_b(), on_result=lambda r: got_b.append(np.asarray(r)),
           rekey_every_n=3)
    assert pb.directory.epoch >= 2
    assert not pb.directory.is_admitted(Pipeline.worker_id("s3", 1))
    assert len(got_b) == len(got_static)
    for a, b in zip(got_b, got_static):
        assert np.array_equal(a, b)                    # bit-identical


def test_rekey_never_reuses_a_key_nonce_pair(monkeypatch):
    """Regression: chunk counters are epoch-local, so an executor that
    resealed a drained old-epoch chunk under the *current* epoch would
    collide with the new epoch's own counters — a two-time pad.  Spy on
    every AEAD seal across a rekey+revocation run — the scalar path AND
    every row of the window-batched ``seal_many`` path — and assert no
    (key, nonce) pair is ever issued twice."""
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline
    from repro.crypto import aead

    seen = set()
    real_seal = aead.seal
    real_seal_many = aead.seal_many

    def record(key_row, nonce_row):
        kn = (np.asarray(key_row).tobytes(), np.asarray(nonce_row).tobytes())
        assert kn not in seen, "(key, nonce) pair reused across epochs"
        seen.add(kn)

    def spy(key, nonce, words):
        record(key, nonce)
        return real_seal(key, nonce, words)

    def spy_many(key, nonces, words, **kw):
        key = np.asarray(key)
        for b in range(np.asarray(nonces).shape[0]):
            record(key if key.ndim == 1 else key[b],
                   np.asarray(nonces)[b])
        return real_seal_many(key, nonces, words, **kw)

    monkeypatch.setattr(aead, "seal", spy)
    monkeypatch.setattr(aead, "seal_many", spy_many)
    p = Pipeline(_stage8()[:4], SecureStreamConfig(mode="encrypted"))
    src = [jnp.full((16,), float(i + 1), jnp.float32) for i in range(9)]

    def source():
        for i, c in enumerate(src):
            if i == 5:
                p.directory.revoke(Pipeline.worker_id("s0", 1))
            yield c

    got = []
    p.run(source(), on_result=lambda r: got.append(np.asarray(r)),
          rekey_every_n=3)
    assert p.directory.epoch >= 2 and len(got) == len(src)
    assert len(seen) > len(src)        # ingress + every edge resealed
    # a SECOND run on the same pipeline continues the managed counters —
    # re-enumerating from 0 would reseal fresh plaintext under the first
    # run's (key, nonce) pairs (the spy would trip)
    got2 = []
    p.run(iter([jnp.full((16,), 99.0, jnp.float32)] * 2),
          on_result=lambda r: got2.append(np.asarray(r)))
    assert len(got2) == 2


def test_scale_stage_admits_only_verified_workers():
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline
    p = Pipeline(_stage8()[:2], SecureStreamConfig(mode="encrypted"))
    wid = Pipeline.worker_id("s1", 0)
    assert p.directory.is_admitted(wid)
    p.directory.revoke(wid)
    p2 = p.scale_stage("s1", 3)
    assert p2.directory is p.directory
    assert not p2.directory.is_admitted(wid)           # stays evicted
    assert p2.directory.is_admitted(Pipeline.worker_id("s1", 1))
    assert p2.directory.is_admitted(Pipeline.worker_id("s1", 2))
    # the stream still runs on the survivors
    out = []
    p2.run(iter([jnp.ones((8,), jnp.float32)]),
           on_result=lambda r: out.append(np.asarray(r)))
    assert len(out) == 1
    # revoking EVERY worker of a stage stalls the stage (a stage-level
    # error, NOT RevokedWorkerError — a stage name is not a worker id)
    for w in range(3):
        p2.directory.revoke(Pipeline.worker_id("s1", w))
    with pytest.raises(KeyDirectoryError, match="every worker"):
        p2.run(iter([jnp.ones((8,), jnp.float32)]))


def test_pipeline_parallel_rekey_across_epoch_boundary():
    """GPipe with rekey_every_n=2 over 6 ticks: hand-offs sealed in epoch E
    open after the flip; output equals the unsealed run exactly."""
    from repro.dist.pipeline_parallel import edge_directory, pipeline_apply
    S, M, mb, d_model = 4, 3, 2, 8
    W = jax.random.normal(jax.random.key(0), (S, d_model, d_model))
    xs = jax.random.normal(jax.random.key(1), (M, mb, d_model))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    want = pipeline_apply(stage_fn, W, xs, None, seal=False)
    d = edge_directory(S, seed=3)
    out = pipeline_apply(stage_fn, W, xs, None, seal=True, directory=d,
                         rekey_every_n=2)
    assert d.epoch >= 2                                # flips happened
    assert float(jnp.abs(out - want).max()) == 0.0     # exact roundtrip


def test_secure_exchange_with_directory_handle():
    from repro.dist import collectives
    d = _directory()
    d.establish("shuffle", "a", "b")
    h = d.handle("shuffle")
    mesh = jax.make_mesh((1,), ("model",))
    x = jax.random.normal(jax.random.key(3), (1, 1, 16, 4), jnp.float32)
    y, ok = collectives.secure_exchange(x, mesh, "model", key=h)  # no step
    assert bool(ok.all())
    assert d.session("shuffle").chunks == 1            # managed counter
    y2, ok2 = collectives.secure_exchange(x, mesh, "model", key=h)
    assert bool(ok2.all()) and d.session("shuffle").chunks == 2
    # each round reserves the FULL W^2 nonce block, so another consumer
    # of the same edge (SecureChannel etc.) can never land inside it
    assert d.next_counters("shuffle", 4) == 2
    assert d.session("shuffle").chunks == 6
    # raw StageKey without a step is still a hard error
    with pytest.raises(ValueError, match="explicit per-round step"):
        collectives.secure_exchange(x, mesh, "model", key=h.key())
    # handle + explicit step would bypass the managed counter and later
    # collide with a managed allocation of the same value -> rejected
    with pytest.raises(ValueError, match="manages its own round"):
        collectives.secure_exchange(x, mesh, "model", key=h, step=5)


def test_rekey_history_guard_rejects_unsafe_combo():
    """A rekey cadence that could prune keys still needed to drain the
    in-flight window must fail up front, not NoSessionError mid-run."""
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline, Stage
    p = Pipeline([Stage("s", op="scale_f32", const=2.0, workers=9)],
                 SecureStreamConfig(mode="encrypted"))
    with pytest.raises(ValueError, match="epoch_history"):
        p.run(iter([jnp.ones((8,), jnp.float32)] * 12), rekey_every_n=1)


def test_plain_mode_skips_handshakes():
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline, Stage
    p = Pipeline([Stage("s", op="scale_f32", const=2.0, workers=2)],
                 SecureStreamConfig(mode="plain"))
    assert p.directory.edges() == []           # no sessions established
    assert p.keys == [None, None]
    assert p.directory.is_admitted(Pipeline.worker_id("s", 0))  # still gated
    out = []
    p.run(iter([jnp.ones((8,), jnp.float32)]),
          on_result=lambda r: out.append(np.asarray(r)))
    assert np.allclose(out[0], 2.0)


def test_secure_channel_epoch_drain():
    from repro.core.secure_channel import SecureChannel
    d = _directory()
    d.establish("e", "a", "b")
    ch = SecureChannel(d.handle("e"))
    x = jnp.arange(12, dtype=jnp.float32)
    hdr, ct, tag, meta = ch.protect(x)         # sealed in epoch 0
    d.advance_epoch()
    y, ok = ch.unprotect(hdr, ct, tag, meta)   # opened in epoch 1
    assert bool(ok) and bool((y == x).all())
    hdr2, ct2, tag2, meta2 = ch.protect(x)     # new epoch seals
    assert hdr2[1] == 1 and hdr2[0] == 0       # counter reset by rotation
    y2, ok2 = ch.unprotect(hdr2, ct2, tag2, meta2)
    assert bool(ok2) and bool((y2 == x).all())


# ------------------------------------------------------------- grep gate


def test_derive_stage_key_has_no_stray_call_sites():
    """Key hygiene: nothing outside repro/crypto and repro/attest derives
    stage keys directly — every sealed path goes through a KeyDirectory.
    (tests/test_crypto_properties.py unit-tests the derivation itself.)"""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    allowed = (os.path.join("src", "repro", "crypto") + os.sep,
               os.path.join("src", "repro", "attest") + os.sep)
    offenders = []
    for sub in ("src", "benchmarks", "examples"):
        for dirpath, _, files in os.walk(os.path.join(root, sub)):
            for fname in files:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                if rel.startswith(allowed):
                    continue
                text = open(path, encoding="utf-8").read()
                if re.search(r"derive_stage_key\s*\(", text):
                    offenders.append(rel)
    assert offenders == [], (
        f"derive_stage_key called outside repro.crypto/repro.attest: "
        f"{offenders} — obtain keys from a KeyDirectory instead")
