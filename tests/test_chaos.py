"""Chaos harness for the window engine (repro.ft).

The contract under test is the ISSUE's acceptance bar: under seeded
fault schedules — worker crashes (transient and fatal), stalled shares
raced by speculative backups, tampered windows, dropped MAC-verdict
syncs, failed live enrollments — the terminal reduce of the 8-stage
encrypted job is BIT-IDENTICAL to the fault-free oracle, every injected
fault lands in the audit stream exactly once, and no re-execution ever
re-spends a (key, nonce) pair (the replay-buffer nonce discipline).
"""
from __future__ import annotations

from collections import Counter

import jax.numpy as jnp
import numpy as np
import pytest

from repro.attest.directory import KeyDirectory
from repro.configs.base import SecureStreamConfig
from repro.core.pipeline import Pipeline, Stage
from repro.ft.chaos import ChaosPlan, FaultSpec
from repro.ft.recovery import ReplayBuffer
from repro.ft.retry import RetryPolicy
from repro.ft.straggler import BackupDispatcher, StragglerDetector

N_CHUNKS = 12
CHUNK = 64


def _sum_reduce(acc, x):
    return x if acc is None else acc + x


def _stages8():
    sts = [Stage(f"s{i}", "scale_f32", const=1.0 + 0.125 * i,
                 workers=2 if i == 2 else 1) for i in range(8)]
    sts.append(Stage("sink", "custom", reduce_fn=_sum_reduce,
                     reduce_init=None))
    return sts


TOPOLOGY = [(f"s{i}", 2 if i == 2 else 1) for i in range(8)]


def _build(chaos=None, retry=None, seed=7, mode="encrypted",
           window_chunks=4):
    d = KeyDirectory(seed=seed, epoch_history=64)
    return Pipeline(_stages8(), SecureStreamConfig(mode=mode), seed=seed,
                    directory=d, window_chunks=window_chunks,
                    retry=retry, chaos=chaos)


def _source():
    return [jnp.asarray(
        np.random.RandomState(41 + i).rand(CHUNK).astype(np.float32))
        for i in range(N_CHUNKS)]


_ORACLE = {}


def _oracle(rekey=None):
    """Fault-free terminal reduce, computed once per rekey cadence."""
    if rekey not in _ORACLE:
        _ORACLE[rekey] = np.asarray(
            _build().run(iter(_source()), rekey_every_n=rekey))
    return _ORACLE[rekey]


def _ft_events(audit, *kinds):
    """Audit events of the given kinds as (kind, detail) pairs."""
    keep = set(kinds)
    return [(e["kind"], e) for e in audit.dump() if e["kind"] in keep]


# ------------------------------------------------------------- seeded sweep


@pytest.mark.parametrize("seed", range(20))
def test_seeded_chaos_sweep_bit_identical(seed):
    """20 seeded fault schedules over the 8-stage encrypted job: the
    terminal reduce is bit-identical to the fault-free oracle and every
    fired fault has its exactly-once audit footprint."""
    plan = ChaosPlan.seeded(seed, TOPOLOGY, rounds=3, n_faults=3)
    # pinned stall cutoff: injected stalls (>= 0.5 s) always exceed it,
    # so the stall -> backup decision is deterministic on any machine
    policy = RetryPolicy(share_timeout_s=0.25)
    p = _build(chaos=plan, retry=policy, seed=100 + seed)
    out = p.run(iter(_source()))
    assert np.array_equal(_oracle(), np.asarray(out)), \
        f"seed {seed}: terminal reduce diverged from the oracle"

    dump = p.directory.audit.dump()
    fired = {f.kind: [] for f in plan.faults}
    for (kind, stage, rnd, w) in plan.events:
        if kind == "enroll_fail":
            continue
        fired.setdefault(kind, []).append((stage, rnd, w))

    def _wf(reason, stage, rnd, w):
        return [e for e in dump if e["kind"] == "worker_failed"
                and e.get("reason") == reason and e.get("stage") == stage
                and e.get("round") == rnd
                and e.get("worker") == f"{stage}/w{w}"]

    for stage, rnd, w in fired.get("crash", []):
        assert len(_wf("crash", stage, rnd, w)) == 1, \
            f"seed {seed}: crash at {(stage, rnd, w)} not audited once"
        follow = [e for e in dump
                  if e["kind"] in ("share_retried", "share_failover")
                  and e.get("stage") == stage and e.get("round") == rnd]
        assert follow, f"seed {seed}: crash at {(stage, rnd, w)} " \
                       f"triggered neither retry nor failover"
    for stage, rnd, w in fired.get("stall", []):
        assert len(_wf("stall", stage, rnd, w)) == 1, \
            f"seed {seed}: stall at {(stage, rnd, w)} not audited once"
    # replays are audited once per affected SHARE; two faults may share a
    # (stage, round) via different workers, so count grouped
    for reason, kind in (("mac_failure", "tamper"),
                         ("verdict_dropped", "drop_verdict")):
        want = Counter((s, r) for s, r, _ in fired.get(kind, []))
        got = Counter((e["stage"], e["round"]) for e in dump
                      if e["kind"] == "window_replayed"
                      and e.get("reason") == reason)
        assert got == want, \
            f"seed {seed}: {kind} replays {dict(got)} != fired {dict(want)}"
        if kind == "tamper":
            for (stage, _r) in want:
                assert any(e["kind"] == "mac_failure"
                           and e.get("stage") == stage for e in dump), \
                    f"seed {seed}: tamper at {stage} left no mac_failure"


# --------------------------------------------------------- nonce discipline


def test_chaos_recovery_never_reuses_key_nonce(monkeypatch):
    """The FT invariant: a retried / failed-over / replayed share must
    never reseal under a (key, nonce) pair already spent on the outbound
    key.  Spy on every AEAD seal (scalar and batched) across a fault
    schedule that exercises retry, tamper-replay, AND verdict-drop
    replay; any reuse trips the spy."""
    from repro.crypto import aead

    # the oracle pipeline shares the chaos run's key seed — build it
    # BEFORE arming the spy or its (identical) ingress seals false-trip
    want = _oracle(rekey=3)
    seen = set()
    real_seal, real_seal_many = aead.seal, aead.seal_many

    def record(key_row, nonce_row):
        kn = (np.asarray(key_row).tobytes(),
              np.asarray(nonce_row).tobytes())
        assert kn not in seen, "(key, nonce) pair reused by a recovery"
        seen.add(kn)

    def spy(key, nonce, words):
        record(key, nonce)
        return real_seal(key, nonce, words)

    def spy_many(key, nonces, words, **kw):
        key = np.asarray(key)
        for b in range(np.asarray(nonces).shape[0]):
            record(key if key.ndim == 1 else key[b],
                   np.asarray(nonces)[b])
        return real_seal_many(key, nonces, words, **kw)

    monkeypatch.setattr(aead, "seal", spy)
    monkeypatch.setattr(aead, "seal_many", spy_many)

    plan = ChaosPlan(faults=[
        # crash AFTER the share ran: the original coordinates were
        # already spent on the outbound key — the harshest retry case
        FaultSpec("crash", stage="s1", round=0, worker=0, when="after"),
        FaultSpec("crash", stage="s3", round=1, worker=0, when="after"),
        FaultSpec("tamper", stage="s4", round=0, worker=0, rows=2),
        FaultSpec("drop_verdict", stage="s6", round=1, worker=0),
    ])
    p = _build(chaos=plan, retry=RetryPolicy())
    out = p.run(iter(_source()), rekey_every_n=3)
    assert not plan.pending()
    assert np.array_equal(want, np.asarray(out))
    assert len(seen) > N_CHUNKS          # ingress + every resealed hop


# ------------------------------------------------------- acceptance scenario


def test_acceptance_rekey3_crash_stall_enroll_failure():
    """The ISSUE's acceptance run: 8-stage encrypted pipeline,
    ``rekey_every_n=3``, a seeded schedule with a fatal worker crash
    (forcing a live spare enrollment whose first handshake fails), a
    stalled share lost to a speculative backup, and the injected
    enrollment failure — terminal reduce bit-identical, each fault in
    the ordered audit stream exactly once."""
    plan = ChaosPlan(faults=[
        FaultSpec("crash", stage="s4", round=0, worker=0, when="after",
                  fatal=True),
        FaultSpec("enroll_fail"),
        FaultSpec("stall", stage="s2", round=1, worker=0, seconds=0.8),
    ])
    p = _build(chaos=plan, retry=RetryPolicy(share_timeout_s=0.25))
    out = p.run(iter(_source()), rekey_every_n=3)
    assert np.array_equal(_oracle(rekey=3), np.asarray(out))
    assert not plan.pending()            # every fault fired

    dump = p.directory.audit.dump()
    counts = Counter(e["kind"] for e in dump)
    # the fatal crash: one worker_failed, >=1 failover off the dead
    # worker, and the stage grew exactly one admitted spare
    crash = [e for e in dump if e["kind"] == "worker_failed"
             and e.get("reason") == "crash"]
    assert len(crash) == 1 and crash[0]["fatal"] is True
    assert counts["share_failover"] >= 2          # crash + backup
    s4 = next(s for s in p.stages if s.name == "s4")
    assert s4.workers == 2
    assert p.directory.is_admitted("s4/w1")
    # the chaos-injected enrollment failure took the REAL admission
    # path: exactly one quote_rejected in the same ordered stream
    rejected = [e for e in dump if e["kind"] == "quote_rejected"]
    assert len(rejected) == 1
    assert "chaos" in rejected[0]["reason"]
    # the stall: one worker_failed(stall), and the backup won the race
    stall = [e for e in dump if e["kind"] == "worker_failed"
             and e.get("reason") == "stall"]
    assert len(stall) == 1 and stall[0]["stage"] == "s2"
    backup = [e for e in dump if e["kind"] == "share_failover"
              and e.get("reason") == "backup"]
    assert len(backup) == 1 and backup[0]["stage"] == "s2"
    # epochs actually rotated under all of this
    assert p.directory.epoch >= 2


def test_chaos_plan_replays_bit_for_bit():
    """``replay()`` resets the schedule: the same plan fires the same
    faults at the same addresses on a second run, and both runs produce
    the oracle's bits."""
    plan = ChaosPlan.seeded(5, TOPOLOGY, rounds=3, n_faults=3)
    p = _build(chaos=plan, retry=RetryPolicy(share_timeout_s=0.25))
    out1 = np.asarray(p.run(iter(_source())))
    events1 = list(plan.events)
    plan.replay()
    assert plan.events == [] and all(not f.fired for f in plan.faults)
    out2 = np.asarray(p.run(iter(_source())))
    assert plan.events == events1
    assert np.array_equal(out1, out2)
    assert np.array_equal(out1, _oracle())


# ----------------------------------------------------- engine interlocks


def test_ft_requires_window_engine():
    p = _build(retry=RetryPolicy())
    with pytest.raises(ValueError, match="window_chunks"):
        p.run(iter(_source()), window_chunks=1)


def test_fresh_coords_come_from_ingress_edge():
    """Re-execution counters are reserved from edge0 (the one allocator
    whose blocks are globally collision-free); plain mode has none."""
    p = _build()
    before = p.directory.session("edge0").chunks
    counters, epoch = p._ft_fresh_coords(4)
    assert counters == list(range(before, before + 4))
    assert p.directory.session("edge0").chunks == before + 4
    assert epoch == p.directory.epoch
    plain = Pipeline(_stages8(), SecureStreamConfig(mode="plain"),
                     window_chunks=4)
    assert plain._ft_fresh_coords(4) is None


def test_enclave_mode_chaos_bit_identical():
    """The fused in-enclave kernel path: re-sealing under separate
    outbound (nonce, counter) coordinates (the kernel's new FT inputs)
    preserves bit-identity through crash-retry and tamper-replay."""
    sts = [Stage("a", "scale_f32", const=1.5, workers=2),
           Stage("b", "relu_f32"),
           Stage("sink", "custom", reduce_fn=_sum_reduce,
                 reduce_init=None)]
    src = [jnp.asarray(
        np.random.RandomState(3 + i).rand(32).astype(np.float32) - 0.5)
        for i in range(8)]

    def build(chaos=None, retry=None):
        return Pipeline(sts, SecureStreamConfig(mode="enclave"), seed=3,
                        directory=KeyDirectory(seed=3, epoch_history=64),
                        window_chunks=4, retry=retry, chaos=chaos)

    oracle = np.asarray(build().run(iter(src)))
    plan = ChaosPlan(faults=[
        FaultSpec("crash", stage="a", round=0, worker=1, when="after"),
        FaultSpec("tamper", stage="b", round=0, worker=0, rows=1),
    ])
    out = np.asarray(build(chaos=plan, retry=RetryPolicy()).run(iter(src)))
    assert not plan.pending()
    assert np.array_equal(oracle, out)


def test_enclave_rows_kernel_out_coords_match_ref():
    """Kernel-level parity for the FT re-seal inputs: with distinct
    outbound (nonce, counter) columns, the fused kernel matches the
    pure-jnp oracle, and the default (no out coords) is unchanged."""
    from repro.kernels.enclave_map.enclave_map import enclave_apply_rows
    from repro.kernels.enclave_map.ref import enclave_apply_rows_ref

    rng = np.random.default_rng(0)
    R = 8
    kin = jnp.asarray(rng.integers(0, 2**32, (R, 8), dtype=np.uint32))
    kout = jnp.asarray(rng.integers(0, 2**32, (R, 8), dtype=np.uint32))
    data = jnp.asarray(rng.integers(0, 2**32, (R, 16), dtype=np.uint32))
    nin = jnp.asarray(rng.integers(0, 2**32, (R, 3), dtype=np.uint32))
    nout = jnp.asarray(rng.integers(0, 2**32, (R, 3), dtype=np.uint32))
    cin = jnp.arange(1, R + 1, dtype=jnp.uint32)
    cout = jnp.arange(101, R + 101, dtype=jnp.uint32)
    got = enclave_apply_rows(kin, kout, nin, cin, data, op="scale_f32",
                             const=2.0, block_rows=R, interpret=True,
                             nonces_out=nout, counters_out=cout)
    want = enclave_apply_rows_ref(np.asarray(kin), np.asarray(kout),
                                  np.asarray(nin), np.asarray(cin),
                                  np.asarray(data), op="scale_f32",
                                  const=2.0, nonces_out=np.asarray(nout),
                                  counters_out=np.asarray(cout))
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # distinct out-coords genuinely change the ciphertext
    same = enclave_apply_rows(kin, kout, nin, cin, data, op="scale_f32",
                              const=2.0, block_rows=R, interpret=True)
    assert not np.array_equal(np.asarray(got), np.asarray(same))


# ------------------------------------------------------------- DSL surface


def test_dsl_retry_and_chaos_verbs():
    src = _source()
    plan = ChaosPlan(faults=[
        FaultSpec("crash", stage="m", round=0, worker=0)])
    from repro.dsl import stream
    b = (stream(src).map("scale_f32", const=2.0, name="m", workers=2)
         .reduce(_sum_reduce, None, name="r")
         .secure("encrypted").window(4)
         .retry(RetryPolicy(max_attempts=2)).chaos(plan))
    assert b.retry_policy.max_attempts == 2
    assert b.chaos_plan is plan
    out = b.run()
    want = (stream(src).map("scale_f32", const=2.0, name="m", workers=2)
            .reduce(_sum_reduce, None, name="r")
            .secure("encrypted").window(4)).run()
    assert np.array_equal(np.asarray(want), np.asarray(out))
    assert plan.events == [("crash", "m", 0, 0)]
    seq = b.pipeline.directory.audit.kind_sequence(
        "worker_failed", "share_retried")
    assert seq == ["worker_failed", "share_retried"]


# ---------------------------------------------------------------- ft units


def test_replay_buffer_retain_ack_watermark():
    buf = ReplayBuffer()

    class _W(list):
        pass

    w = _W([1, 2, 3])
    buf.retain("s0", 0, [w])
    assert buf.retained_rows() == 3
    assert buf.get("s0", 0) == [w]
    assert buf.watermark() == -1
    buf.ack("s0", 0)
    assert buf.retained_rows() == 0 and buf.get("s0", 0) is None
    buf.retain("s1", 1, [w, w])
    buf.ack("s1", 1)
    assert buf.watermark() == 0          # min over stages: s0 acked 0


def test_backup_dispatcher_track_and_reissue():
    d = BackupDispatcher(num_workers=3)
    d.track(7, 2)                        # engine-chosen assignment
    assert d.reissue(7) == 0             # backup goes to the NEXT worker
    assert d.complete(7) is True
    assert d.complete(7) is False and d.duplicates == 1
    assert d.reissue(7) is None          # completed: nothing to reissue


def test_retry_policy_backoff_and_timeout():
    pol = RetryPolicy(backoff_base_s=0.1, backoff_factor=2.0,
                      max_backoff_s=0.3)
    assert pol.backoff(1) == pytest.approx(0.1)
    assert pol.backoff(2) == pytest.approx(0.2)
    assert pol.backoff(5) == pytest.approx(0.3)      # capped
    assert RetryPolicy().backoff(3) == 0.0           # immediate default
    det = StragglerDetector()
    pol2 = RetryPolicy(min_timeout_s=0.05, timeout_scale=4.0)
    assert pol2.timeout_for(det) == 0.05             # cold: floor
    for _ in range(det.warmup + 3):
        det.observe(0.1)
    assert pol2.timeout_for(det) == pytest.approx(4.0 * det.mean)
    assert RetryPolicy(share_timeout_s=1.5).timeout_for(det) == 1.5
