"""Fault tolerance: sealed checkpoints, recovery, stragglers, trainer e2e."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_model_config, reduce_for_smoke
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.dist.meshctx import local_mesh_context
from repro.ft.failures import FailureInjector, run_with_recovery
from repro.ft.straggler import BackupDispatcher, StragglerDetector
from repro.models import api
from repro.optim import make_optimizer


def _tiny_state(seed=0):
    params = {"w": jnp.arange(6.0).reshape(2, 3),
              "b": jnp.ones((3,), jnp.bfloat16)}
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    return params, opt


def test_sealed_checkpoint_roundtrip(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    ckpt.save(path, 7, params, opt, sealed=True)
    step, p2, o2 = ckpt.restore(path, params_like=params, opt_like=opt)
    assert step == 7
    assert all(bool((a == b).all()) for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(p2)))


def test_sealed_checkpoint_tamper_detected(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    final = ckpt.save(path, 3, params, opt, sealed=True)
    blob_path = os.path.join(final, "arrays.sealed")
    with open(blob_path, "r+b") as f:
        f.seek(100)
        byte = f.read(1)
        f.seek(100)
        f.write(bytes([byte[0] ^ 0x01]))
    with pytest.raises(ValueError, match="AEAD verification FAILED"):
        ckpt.restore(path, params_like=params, opt_like=opt)


def test_sealed_checkpoint_truncation_detected(tmp_path):
    """Dropping trailing rows + their tags + shrinking n_bytes must fail
    the tag-list MAC — per-row MACs alone can't bind the row count."""
    import json
    params = {"w": jnp.zeros((10000,), jnp.float32)}   # ~40KB -> 3 rows
    opt = {}
    path = str(tmp_path / "ck")
    final = ckpt.save(path, 2, params, opt, sealed=True)
    man_path = os.path.join(final, "manifest.json")
    man = json.load(open(man_path))
    row_bytes = man["aead"]["row_words"] * 4
    blob_path = os.path.join(final, "arrays.sealed")
    blob = open(blob_path, "rb").read()
    assert len(blob) // row_bytes >= 2
    with open(blob_path, "wb") as f:                   # drop the last row
        f.write(blob[:-row_bytes])
    man["aead"]["tags"] = man["aead"]["tags"][:-16]    # ...and its tag
    man["aead"]["n_bytes"] = (len(blob) - row_bytes)   # ...and the length
    json.dump(man, open(man_path, "w"))
    with pytest.raises(ValueError, match="tag list"):
        ckpt.restore(path, params_like=params, opt_like=opt)


def test_sealed_checkpoints_never_share_keystream(tmp_path):
    """Two stores sealed with the same seed + step must not reuse a
    ChaCha20 keystream: XOR of the blobs must not equal XOR of the
    plaintexts (the per-store salt separates the keys)."""
    a = {"w": jnp.zeros((4096,), jnp.float32)}
    b = {"w": jnp.ones((4096,), jnp.float32)}
    fa = ckpt.save(str(tmp_path / "a"), 5, a, {}, sealed=True, seed=0)
    fb = ckpt.save(str(tmp_path / "b"), 5, b, {}, sealed=True, seed=0)
    ba = open(os.path.join(fa, "arrays.sealed"), "rb").read()
    bb = open(os.path.join(fb, "arrays.sealed"), "rb").read()
    n = min(len(ba), len(bb))
    xor = np.frombuffer(ba[:n], np.uint8) ^ np.frombuffer(bb[:n], np.uint8)
    # identical keystream would make large runs of the XOR equal the
    # plaintext XOR (mostly the float32 pattern of 1.0); distinct salts
    # make the XOR look uniformly random
    assert np.unique(xor).size > 64


def test_checkpoint_wrong_seed_fails(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    ckpt.save(path, 1, params, opt, sealed=True, seed=0)
    with pytest.raises(ValueError):
        ckpt.restore(path, params_like=params, opt_like=opt, seed=99)


def test_latest_step_selection(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    for s in (5, 10, 20):
        ckpt.save(path, s, params, opt, sealed=False)
    assert ckpt.latest_step(path) == 20
    step, _, _ = ckpt.restore(path, params_like=params, opt_like=opt)
    assert step == 20


def test_run_with_recovery_restarts():
    log = []
    state = {"step": 0, "ckpt": 0}
    inj = FailureInjector(schedule={7: "node_loss", 13: "ici_timeout"})

    def run_steps(start, end):
        for s in range(start, end):
            inj.maybe_fail(s)
            state["step"] = s + 1
            if (s + 1) % 5 == 0:
                state["ckpt"] = s + 1
            log.append(s)
        return state["step"]

    def restore():
        state["step"] = state["ckpt"]
        return state["ckpt"]

    rep = run_with_recovery(total_steps=20, run_steps=run_steps,
                            restore=restore)
    assert rep.final_step == 20
    assert rep.restarts == 2
    assert rep.replayed_steps > 0  # steps 5..7 and 10..13 replayed


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(warmup=5, threshold=3.0)
    flags = [det.observe(0.1 + 0.001 * i) for i in range(20)]
    assert not any(flags)
    assert det.observe(1.5)  # 15x step time -> straggler


def test_backup_dispatcher_dedup():
    d = BackupDispatcher(num_workers=4)
    w0 = d.assign(0)
    wb = d.reissue(0)
    assert wb != w0
    assert d.complete(0) is True
    assert d.complete(0) is False  # duplicate completion deduped
    assert d.duplicates == 1


def test_recovery_restores_epoch_n_ckpt_resumes_epoch_n_plus_1(tmp_path):
    """Recovery x rekeying interplay: the supervisor restores from a sealed
    checkpoint taken in epoch N and resumes the sealed stream after the
    directory has ratcheted to epoch N+1 — final state parity with an
    uninterrupted run (chunks re-seal under whatever the live epoch is)."""
    from repro.attest.directory import KeyDirectory
    from repro.attest.measure import IO_ENDPOINT
    from repro.core.secure_channel import SecureChannel

    TOTAL, CKPT_EVERY, REKEY_AT, FAIL_AT = 12, 5, 6, 9
    like = {"acc": jnp.zeros((8,), jnp.float32)}

    def build_directory():
        d = KeyDirectory(seed=5)
        d.enroll("io/src", IO_ENDPOINT, allow=True)
        d.enroll("io/snk", IO_ENDPOINT, allow=True)
        d.establish("stream", "io/src", "io/snk")
        return d

    def data(step):
        return jnp.full((8,), float(step + 1), jnp.float32)

    def run(path, injector):
        directory = build_directory()
        ch = SecureChannel(directory.handle("stream"))
        state = {"acc": np.zeros((8,), np.float32), "step": 0}

        def run_steps(start, end):
            for s in range(start, end):
                if injector is not None:
                    injector.maybe_fail(s)
                if s == REKEY_AT:
                    directory.advance_epoch()          # epoch N -> N+1
                hdr, ct, tag, meta = ch.protect(data(s))
                x, ok = ch.unprotect(hdr, ct, tag, meta)
                assert bool(ok)
                state["acc"] = state["acc"] + np.asarray(x)
                state["step"] = s + 1
                if state["step"] % CKPT_EVERY == 0:
                    ckpt.save(path, state["step"], {"acc": state["acc"]}, {},
                              sealed=True, seed=5,
                              extra={"epoch": directory.epoch})
            return state["step"]

        def restore():
            last = ckpt.latest_step(path)
            if last is None:
                return 0
            step, p, _ = ckpt.restore(path, last, seed=5, params_like=like,
                                      opt_like={})
            state["acc"], state["step"] = np.asarray(p["acc"]), step
            return step

        rep = run_with_recovery(total_steps=TOTAL, run_steps=run_steps,
                                restore=restore, directory=directory)
        return state["acc"], rep, directory

    # uninterrupted reference
    acc_ref, rep_ref, _ = run(str(tmp_path / "ref"), None)
    assert rep_ref.restarts == 0

    # failure at step 9: restores the step-5 checkpoint (sealed in epoch 0)
    # while the directory is already at epoch 1
    inj = FailureInjector(schedule={FAIL_AT: "node_loss"})
    path = str(tmp_path / "ck")
    acc, rep, directory = run(path, inj)
    assert rep.restarts == 1 and rep.replayed_steps > 0
    assert directory.epoch >= 1                       # resumed post-rekey
    import json, os
    man = json.load(open(os.path.join(path, "step-%08d" % CKPT_EVERY,
                                      "manifest.json")))
    assert man["extra"]["epoch"] == 0                 # ckpt taken in epoch N
    assert np.array_equal(acc, acc_ref)               # output parity


def test_trainer_end_to_end_with_failure(tmp_path):
    """Tiny LM, 24 steps, injected failure at step 15: trainer recovers from
    the sealed checkpoint, loss decreases overall."""
    from repro.train.trainer import Trainer, TrainerConfig

    ctx = local_mesh_context()
    cfg = reduce_for_smoke(get_model_config("llama3.2-1b"))
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("tiny", 16, 4, "train"),
                    optimizer=OptimizerConfig(lr=5e-3, warmup_steps=5),
                    remat="none")

    def data_fn(step):
        rng = np.random.default_rng(step)  # deterministic per step (replay!)
        # learnable signal: noisy modular ramps (next-token predictable)
        start = rng.integers(0, cfg.vocab_size, (4, 1))
        ramp = (start + np.arange(17)[None]) % cfg.vocab_size
        noise = rng.integers(0, cfg.vocab_size, ramp.shape)
        keep = rng.random(ramp.shape) < 0.95
        toks = np.where(keep, ramp, noise).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    tcfg = TrainerConfig(total_steps=24, ckpt_every=8, log_every=4,
                         ckpt_dir=str(tmp_path / "ck"), sealed_ckpt=True,
                         sealed_data=True)
    inj = FailureInjector(schedule={15: "node_loss"})
    tr = Trainer(run, ctx, data_fn, tcfg, injector=inj)
    out = tr.train()
    assert out["final_step"] == 24
    assert out["restarts"] == 1
    assert out["replayed_steps"] > 0
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]  # learning happened across the failure


def test_recovery_before_first_checkpoint_replays_exactly(tmp_path):
    """Regression: a failure BEFORE the first checkpoint must replay from
    step 0 with the partial fold discarded — the old supervisor resumed
    the stale in-memory accumulator and double-folded the replayed
    chunks.  Recovered output must equal the uninterrupted run bit-for-
    bit, under active rekeying."""
    from repro.attest.directory import KeyDirectory
    from repro.attest.measure import IO_ENDPOINT
    from repro.core.secure_channel import SecureChannel

    TOTAL, CKPT_EVERY, REKEY_AT, FAIL_AT = 8, 5, 2, 3
    like = {"acc": jnp.zeros((8,), jnp.float32)}

    def data(step):
        return jnp.full((8,), float(step + 1), jnp.float32)

    def run(path, injector):
        d = KeyDirectory(seed=9)
        d.enroll("io/src", IO_ENDPOINT, allow=True)
        d.enroll("io/snk", IO_ENDPOINT, allow=True)
        d.establish("stream", "io/src", "io/snk")
        ch = SecureChannel(d.handle("stream"))
        state = {"acc": np.zeros((8,), np.float32), "step": 0}

        def run_steps(start, end):
            for s in range(start, end):
                if injector is not None:
                    injector.maybe_fail(s)
                if s == REKEY_AT:
                    d.advance_epoch()
                hdr, ct, tag, meta = ch.protect(data(s))
                x, ok = ch.unprotect(hdr, ct, tag, meta)
                assert bool(ok)
                state["acc"] = state["acc"] + np.asarray(x)
                state["step"] = s + 1
                if state["step"] % CKPT_EVERY == 0:
                    ckpt.save(path, state["step"],
                              {"acc": state["acc"]}, {}, sealed=True,
                              seed=9)
            return state["step"]

        def restore():
            last = ckpt.latest_step(path)
            if last is None:
                # no checkpoint: the replay starts from a CLEAN fold —
                # keeping the partial acc is exactly the fixed bug
                state["acc"] = np.zeros((8,), np.float32)
                state["step"] = 0
                return 0
            step, p, _ = ckpt.restore(path, last, seed=9,
                                      params_like=like, opt_like={})
            state["acc"], state["step"] = np.asarray(p["acc"]), step
            return step

        rep = run_with_recovery(total_steps=TOTAL, run_steps=run_steps,
                                restore=restore)
        return state["acc"], rep

    acc_ref, rep_ref = run(str(tmp_path / "ref"), None)
    assert rep_ref.restarts == 0
    inj = FailureInjector(schedule={FAIL_AT: "node_loss"})
    acc, rep = run(str(tmp_path / "ck"), inj)
    assert rep.restarts == 1
    # exact accounting: steps 0..FAIL_AT-1 were folded then discarded
    assert rep.replayed_steps == FAIL_AT
    assert rep.failures[0][0] == FAIL_AT
    assert np.array_equal(acc, acc_ref)


def test_recovery_rejects_restore_past_the_failure():
    """A restore() that lands AFTER the failure step cannot replay
    exactly (it would skip data or double-fold) — the supervisor must
    refuse instead of silently continuing."""
    inj = FailureInjector(schedule={3: "node_loss"})

    def run_steps(start, end):
        for s in range(start, end):
            inj.maybe_fail(s)
        return end

    calls = {"n": 0}

    def restore():
        calls["n"] += 1
        if calls["n"] == 1:
            return 0        # cold start
        return 6            # stale/foreign checkpoint beyond the failure

    with pytest.raises(RuntimeError, match="past the failure"):
        run_with_recovery(total_steps=10, run_steps=run_steps,
                          restore=restore)


def test_trainer_failure_before_first_ckpt_matches_uninterrupted(tmp_path):
    """Trainer end-to-end regression for the same bug: a failure at step
    3 with ckpt_every=8 (no checkpoint on disk yet) must rewind params
    AND optimizer state to the step-0 snapshot; the recovered run's
    final loss equals the uninterrupted run's exactly (same data_fn,
    same init, full replay)."""
    from repro.train.trainer import Trainer, TrainerConfig

    ctx = local_mesh_context()
    cfg = reduce_for_smoke(get_model_config("llama3.2-1b"))
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("tiny", 16, 4, "train"),
                    optimizer=OptimizerConfig(lr=5e-3, warmup_steps=5),
                    remat="none")

    def data_fn(step):
        rng = np.random.default_rng(step)
        start = rng.integers(0, cfg.vocab_size, (4, 1))
        ramp = (start + np.arange(17)[None]) % cfg.vocab_size
        toks = ramp.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def final_loss(ckdir, injector):
        tcfg = TrainerConfig(total_steps=10, ckpt_every=8, log_every=2,
                             ckpt_dir=ckdir, sealed_ckpt=True)
        tr = Trainer(run, ctx, data_fn, tcfg, injector=injector)
        out = tr.train()
        return out, out["history"][-1]["loss"]

    ref_out, ref_loss = final_loss(str(tmp_path / "ref"), None)
    assert ref_out["restarts"] == 0
    inj = FailureInjector(schedule={3: "node_loss"})
    out, loss = final_loss(str(tmp_path / "ck"), inj)
    assert out["restarts"] == 1
    assert out["replayed_steps"] == 3      # exact: replay 0,1,2
    assert out["final_step"] == 10
    assert loss == ref_loss                # bit-equal full replay
