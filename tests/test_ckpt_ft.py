"""Fault tolerance: sealed checkpoints, recovery, stragglers, trainer e2e."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_model_config, reduce_for_smoke
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.dist.meshctx import local_mesh_context
from repro.ft.failures import FailureInjector, run_with_recovery
from repro.ft.straggler import BackupDispatcher, StragglerDetector
from repro.models import api
from repro.optim import make_optimizer


def _tiny_state(seed=0):
    params = {"w": jnp.arange(6.0).reshape(2, 3),
              "b": jnp.ones((3,), jnp.bfloat16)}
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    return params, opt


def test_sealed_checkpoint_roundtrip(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    ckpt.save(path, 7, params, opt, sealed=True)
    step, p2, o2 = ckpt.restore(path, params_like=params, opt_like=opt)
    assert step == 7
    assert all(bool((a == b).all()) for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(p2)))


def test_sealed_checkpoint_tamper_detected(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    final = ckpt.save(path, 3, params, opt, sealed=True)
    blob_path = os.path.join(final, "arrays.sealed")
    with open(blob_path, "r+b") as f:
        f.seek(100)
        byte = f.read(1)
        f.seek(100)
        f.write(bytes([byte[0] ^ 0x01]))
    with pytest.raises(ValueError, match="Poly1305"):
        ckpt.restore(path, params_like=params, opt_like=opt)


def test_checkpoint_wrong_seed_fails(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    ckpt.save(path, 1, params, opt, sealed=True, seed=0)
    with pytest.raises(ValueError):
        ckpt.restore(path, params_like=params, opt_like=opt, seed=99)


def test_latest_step_selection(tmp_path):
    params, opt = _tiny_state()
    path = str(tmp_path / "ck")
    for s in (5, 10, 20):
        ckpt.save(path, s, params, opt, sealed=False)
    assert ckpt.latest_step(path) == 20
    step, _, _ = ckpt.restore(path, params_like=params, opt_like=opt)
    assert step == 20


def test_run_with_recovery_restarts():
    log = []
    state = {"step": 0, "ckpt": 0}
    inj = FailureInjector(schedule={7: "node_loss", 13: "ici_timeout"})

    def run_steps(start, end):
        for s in range(start, end):
            inj.maybe_fail(s)
            state["step"] = s + 1
            if (s + 1) % 5 == 0:
                state["ckpt"] = s + 1
            log.append(s)
        return state["step"]

    def restore():
        state["step"] = state["ckpt"]
        return state["ckpt"]

    rep = run_with_recovery(total_steps=20, run_steps=run_steps,
                            restore=restore)
    assert rep.final_step == 20
    assert rep.restarts == 2
    assert rep.replayed_steps > 0  # steps 5..7 and 10..13 replayed


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(warmup=5, threshold=3.0)
    flags = [det.observe(0.1 + 0.001 * i) for i in range(20)]
    assert not any(flags)
    assert det.observe(1.5)  # 15x step time -> straggler


def test_backup_dispatcher_dedup():
    d = BackupDispatcher(num_workers=4)
    w0 = d.assign(0)
    wb = d.reissue(0)
    assert wb != w0
    assert d.complete(0) is True
    assert d.complete(0) is False  # duplicate completion deduped
    assert d.duplicates == 1


def test_trainer_end_to_end_with_failure(tmp_path):
    """Tiny LM, 24 steps, injected failure at step 15: trainer recovers from
    the sealed checkpoint, loss decreases overall."""
    from repro.train.trainer import Trainer, TrainerConfig

    ctx = local_mesh_context()
    cfg = reduce_for_smoke(get_model_config("llama3.2-1b"))
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("tiny", 16, 4, "train"),
                    optimizer=OptimizerConfig(lr=5e-3, warmup_steps=5),
                    remat="none")

    def data_fn(step):
        rng = np.random.default_rng(step)  # deterministic per step (replay!)
        # learnable signal: noisy modular ramps (next-token predictable)
        start = rng.integers(0, cfg.vocab_size, (4, 1))
        ramp = (start + np.arange(17)[None]) % cfg.vocab_size
        noise = rng.integers(0, cfg.vocab_size, ramp.shape)
        keep = rng.random(ramp.shape) < 0.95
        toks = np.where(keep, ramp, noise).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    tcfg = TrainerConfig(total_steps=24, ckpt_every=8, log_every=4,
                         ckpt_dir=str(tmp_path / "ck"), sealed_ckpt=True,
                         sealed_data=True)
    inj = FailureInjector(schedule={15: "node_loss"})
    tr = Trainer(run, ctx, data_fn, tcfg, injector=inj)
    out = tr.train()
    assert out["final_step"] == 24
    assert out["restarts"] == 1
    assert out["replayed_steps"] > 0
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]  # learning happened across the failure
