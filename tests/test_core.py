"""SecureStreams core: observable semantics, routers, pipeline 3-mode
agreement, elastic scaling."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SecureStreamConfig
from repro.core.observable import Observable
from repro.core.pipeline import Pipeline, Stage
from repro.core import router as R
from repro.data.synthetic import (CARRIER_WORD, DELAY_WORD, flight_chunks,
                                  flight_records)

SET = settings(max_examples=15, deadline=None)


# ------------------------------------------------------------- observable


def test_observable_listing2_average_age():
    """The paper's Listing 2: average age of the adult population."""
    ages = np.concatenate([np.full(10, 15.0), np.full(20, 40.0),
                           np.full(10, 60.0)]).astype(np.float32)
    np.random.default_rng(0).shuffle(ages)
    result = (
        Observable.from_array(jnp.asarray(ages), chunk_rows=8)
        .map(lambda c: c)
        .filter(lambda age: age > 18)
        .reduce(lambda acc, age, m: {
            "sum": acc["sum"] + float(jnp.sum(age * m)),
            "count": acc["count"] + float(jnp.sum(m))},
            init={"sum": 0.0, "count": 0.0})
        .subscribe()
    )
    avg = result["sum"] / result["count"]
    expected = (20 * 40 + 10 * 60) / 30
    assert abs(avg - expected) < 1e-3


@SET
@given(st.integers(1, 5), st.integers(8, 64))
def test_observable_map_filter_vs_numpy(seed, n):
    x = np.random.default_rng(seed).standard_normal(n * 4).astype(np.float32)
    out = (Observable.from_array(jnp.asarray(x), chunk_rows=n)
           .map(lambda c: c * 2.0)
           .filter(lambda c: c > 0)
           .reduce(lambda acc, c, m: acc + float(jnp.sum(c * m)), init=0.0)
           .subscribe())
    expected = (x * 2.0)[(x * 2.0) > 0].sum()
    assert abs(out - expected) < 1e-2


def test_observable_window():
    x = jnp.arange(32, dtype=jnp.float32)
    seen = []
    (Observable.from_array(x, chunk_rows=4).window(2)
     .subscribe(on_next=lambda c: seen.append(np.asarray(c))))
    assert all(c.shape == (8,) for c in seen) and len(seen) == 4


# ----------------------------------------------------------------- router


@SET
@given(st.integers(1, 40), st.integers(1, 6))
def test_round_robin_fair_queue_inverse(n_chunks, workers):
    """Outbound round-robin then inbound fair-queue restores stream order."""
    chunks = list(range(n_chunks))
    queues = R.round_robin(chunks, workers)
    merged = list(R.fair_queue(queues))
    assert merged == chunks


@SET
@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 100))
def test_shuffle_by_key_groups(n, num_keys, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, num_keys, n))
    data = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    buckets, counts = R.shuffle_by_key(data, keys, num_keys)
    assert int(counts.sum()) == n
    for k in range(num_keys):
        rows = np.asarray(buckets[k][: int(counts[k])])
        expect = np.asarray(data)[np.asarray(keys) == k]
        assert sorted(map(tuple, rows)) == sorted(map(tuple, expect))


# ------------------------------------------------------------- pipeline


def _flight_pipeline(mode):
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid], minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(carrier[valid],
                                              weights=delay[valid],
                                              minlength=20)
        return acc

    return Pipeline([
        Stage("mapper", op="identity"),
        Stage("filter", op="delay_filter_u32", const=15),
        Stage("reducer", op="custom", reduce_fn=reduce_fn,
              reduce_init={"count": np.zeros(20), "sum": np.zeros(20)}),
    ], SecureStreamConfig(mode=mode))


def _numpy_oracle(n=2048, chunk=256, seed=3):
    recs = flight_records(n, seed=seed)
    delayed = recs[:, DELAY_WORD] > 15
    cnt = np.bincount(recs[delayed, CARRIER_WORD], minlength=20)
    s = np.bincount(recs[delayed, CARRIER_WORD],
                    weights=recs[delayed, DELAY_WORD].astype(np.float64),
                    minlength=20)
    return cnt, s


@pytest.mark.parametrize("mode", ["plain", "encrypted", "enclave"])
def test_pipeline_matches_numpy_oracle(mode):
    p = _flight_pipeline(mode)
    src = (jnp.asarray(c) for c in flight_chunks(2048, 256, seed=3))
    out = p.run(src)
    cnt, s = _numpy_oracle()
    assert np.array_equal(out["count"], cnt)
    assert np.allclose(out["sum"], s)
    rep = p.report()
    assert rep["mapper"]["chunks"] == 8
    assert rep["mapper"]["mac_failures"] == 0


def test_pipeline_drops_tampered_chunk():
    """A corrupted chunk must be dropped (MAC failure), not processed."""
    from repro.core.enclave import ingress
    from repro.crypto.keys import derive_stage_key, root_key_from_seed
    p = _flight_pipeline("enclave")

    class Corrupter:
        def __init__(self, gen):
            self.gen = gen

        def __iter__(self):
            for i, c in enumerate(self.gen):
                yield c

    # easiest corruption point: patch one sealed chunk via a custom source
    # wrapper around the pipeline internals — emulate by running twice and
    # comparing MAC failure accounting with a manually corrupted executor.
    from repro.core.enclave import EnclaveExecutor, seal_tensor
    from repro.crypto.keys import derive_stage_key
    key0 = p.keys[0]
    key1 = p.keys[1]
    ex = EnclaveExecutor("enclave", key0, key1)
    chunk = seal_tensor(key0, 0, jnp.zeros((256, 16), jnp.uint32))
    chunk.blocks = chunk.blocks.at[0, 0].add(np.uint32(1))
    assert ex.run_static("identity", 0.0, chunk) is None
    assert ex.errors == 1


def test_elastic_scale_stage():
    p = _flight_pipeline("plain")
    p2 = p.scale_stage("mapper", 4)
    assert [s.workers for s in p2.stages] == [4, 1, 1]
    # scaled pipeline still computes the same result
    src = (jnp.asarray(c) for c in flight_chunks(1024, 256, seed=3))
    out = p2.run(src)
    assert int(out["count"].sum()) > 0
