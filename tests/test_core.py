"""SecureStreams core: observable semantics, routers, pipeline 3-mode
agreement, elastic scaling."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: use the fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import SecureStreamConfig
from repro.core.observable import Observable
from repro.core.pipeline import Pipeline, Stage
from repro.core import router as R
from repro.data.synthetic import (CARRIER_WORD, DELAY_WORD, flight_chunks,
                                  flight_records)

SET = settings(max_examples=15, deadline=None)


# ------------------------------------------------------------- observable


def test_observable_listing2_average_age():
    """The paper's Listing 2: average age of the adult population."""
    ages = np.concatenate([np.full(10, 15.0), np.full(20, 40.0),
                           np.full(10, 60.0)]).astype(np.float32)
    np.random.default_rng(0).shuffle(ages)
    result = (
        Observable.from_array(jnp.asarray(ages), chunk_rows=8)
        .map(lambda c: c)
        .filter(lambda age: age > 18)
        .reduce(lambda acc, age, m: {
            "sum": acc["sum"] + float(jnp.sum(age * m)),
            "count": acc["count"] + float(jnp.sum(m))},
            init={"sum": 0.0, "count": 0.0})
        .subscribe()
    )
    avg = result["sum"] / result["count"]
    expected = (20 * 40 + 10 * 60) / 30
    assert abs(avg - expected) < 1e-3


@SET
@given(st.integers(1, 5), st.integers(8, 64))
def test_observable_map_filter_vs_numpy(seed, n):
    x = np.random.default_rng(seed).standard_normal(n * 4).astype(np.float32)
    out = (Observable.from_array(jnp.asarray(x), chunk_rows=n)
           .map(lambda c: c * 2.0)
           .filter(lambda c: c > 0)
           .reduce(lambda acc, c, m: acc + float(jnp.sum(c * m)), init=0.0)
           .subscribe())
    expected = (x * 2.0)[(x * 2.0) > 0].sum()
    assert abs(out - expected) < 1e-2


def test_observable_window():
    x = jnp.arange(32, dtype=jnp.float32)
    seen = []
    (Observable.from_array(x, chunk_rows=4).window(2)
     .subscribe(on_next=lambda c: seen.append(np.asarray(c))))
    assert all(c.shape == (8,) for c in seen) and len(seen) == 4


# ----------------------------------------------------------------- router


@SET
@given(st.integers(1, 40), st.integers(1, 6))
def test_round_robin_fair_queue_inverse(n_chunks, workers):
    """Outbound round-robin then inbound fair-queue restores stream order."""
    chunks = list(range(n_chunks))
    queues = R.round_robin(chunks, workers)
    merged = list(R.fair_queue(queues))
    assert merged == chunks


@SET
@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 100))
def test_shuffle_by_key_groups(n, num_keys, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, num_keys, n))
    data = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    buckets, counts = R.shuffle_by_key(data, keys, num_keys)
    assert int(counts.sum()) == n
    for k in range(num_keys):
        rows = np.asarray(buckets[k][: int(counts[k])])
        expect = np.asarray(data)[np.asarray(keys) == k]
        assert sorted(map(tuple, rows)) == sorted(map(tuple, expect))


# ------------------------------------------------------------- pipeline


def _flight_pipeline(mode):
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid], minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(carrier[valid],
                                              weights=delay[valid],
                                              minlength=20)
        return acc

    return Pipeline([
        Stage("mapper", op="identity"),
        Stage("filter", op="delay_filter_u32", const=15),
        Stage("reducer", op="custom", reduce_fn=reduce_fn,
              reduce_init={"count": np.zeros(20), "sum": np.zeros(20)}),
    ], SecureStreamConfig(mode=mode))


def _numpy_oracle(n=2048, chunk=256, seed=3):
    recs = flight_records(n, seed=seed)
    delayed = recs[:, DELAY_WORD] > 15
    cnt = np.bincount(recs[delayed, CARRIER_WORD], minlength=20)
    s = np.bincount(recs[delayed, CARRIER_WORD],
                    weights=recs[delayed, DELAY_WORD].astype(np.float64),
                    minlength=20)
    return cnt, s


@pytest.mark.parametrize("mode", ["plain", "encrypted", "enclave"])
def test_pipeline_matches_numpy_oracle(mode):
    p = _flight_pipeline(mode)
    src = (jnp.asarray(c) for c in flight_chunks(2048, 256, seed=3))
    out = p.run(src)
    cnt, s = _numpy_oracle()
    assert np.array_equal(out["count"], cnt)
    assert np.allclose(out["sum"], s)
    rep = p.report()
    assert rep["mapper"]["chunks"] == 8
    assert rep["mapper"]["mac_failures"] == 0


def test_pipeline_drops_tampered_chunk():
    """A corrupted chunk must be dropped (MAC failure), not processed."""
    p = _flight_pipeline("enclave")

    # easiest corruption point: patch one sealed chunk via a custom source
    # wrapper around the pipeline internals — emulate by running twice and
    # comparing MAC failure accounting with a manually corrupted executor.
    from repro.core.enclave import EnclaveExecutor, seal_tensor
    key0 = p.keys[0]       # KeyDirectory edge handles
    key1 = p.keys[1]
    ex = EnclaveExecutor("enclave", key0, key1)
    chunk = seal_tensor(key0, 0, jnp.zeros((256, 16), jnp.uint32))
    chunk.blocks = chunk.blocks.at[0, 0].add(np.uint32(1))
    assert ex.run_static("identity", 0.0, chunk) is None
    assert ex.errors == 1


def test_elastic_scale_stage():
    p = _flight_pipeline("plain")
    p2 = p.scale_stage("mapper", 4)
    assert [s.workers for s in p2.stages] == [4, 1, 1]
    # scaled pipeline still computes the same result
    src = (jnp.asarray(c) for c in flight_chunks(1024, 256, seed=3))
    out = p2.run(src)
    assert int(out["count"].sum()) > 0


# --------------------------------------------- router policy invariants


def test_round_robin_balance_and_assignment():
    """Chunk i must land on worker i mod W, and queue sizes differ by <=1."""
    chunks = list(range(23))
    queues = R.round_robin(chunks, 4)
    for w, q in enumerate(queues):
        assert q == [c for c in chunks if c % 4 == w]
    sizes = [len(q) for q in queues]
    assert max(sizes) - min(sizes) <= 1


def test_fair_queue_uneven_streams():
    """Fair-queue drains uneven worker streams one chunk per live worker
    per round, never starving a shorter stream."""
    streams = [[0, 3, 6], [1, 4], [2]]
    assert list(R.fair_queue(streams)) == [0, 1, 2, 3, 4, 6]


def test_shuffle_sharded_roundtrip_and_keyed():
    """Mailbox shuffle + keyed routing roundtrip on the local mesh (W=1:
    the collective is an identity but the full shard_map path runs)."""
    import jax
    from repro.attest.directory import ephemeral_edge_key
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh()
    W = int(mesh.shape["model"])
    x = jnp.arange(W * W * 6 * 2, dtype=jnp.float32).reshape(W, W, 6, 2)
    y = R.shuffle_sharded(x, mesh, "model")
    assert np.array_equal(np.asarray(y),
                          np.swapaxes(np.asarray(x), 0, 1))
    # sealed variant: same permutation + all MACs verify
    key = ephemeral_edge_key("shuffle", seed=7)
    ys, ok = R.shuffle_sharded(x, mesh, "model", key=key, step=3)
    assert bool(ok.all())
    assert np.allclose(np.asarray(ys), np.swapaxes(np.asarray(x), 0, 1))
    # keyed policy: every row must come back in the bucket of its key hash
    n = 32
    rows = jnp.asarray(np.random.default_rng(0)
                       .standard_normal((W, n, 3)).astype(np.float32))
    rkeys = jnp.asarray(np.random.default_rng(1).integers(0, 100, (W, n)))
    inbox, counts, ok = R.route_keyed_sharded(rows, rkeys, mesh, "model",
                                              key=key, step=1)
    assert bool(ok.all())
    assert int(np.asarray(counts).sum()) == W * n
    got = sorted(map(tuple, np.asarray(inbox).reshape(-1, 3)
                     [np.asarray(inbox).reshape(-1, 3).any(axis=1)]))
    want = sorted(map(tuple, np.asarray(rows).reshape(-1, 3)))
    assert got == want


# ------------------------------------------------------- worker fan-out


@pytest.mark.parametrize("mode", ["plain", "encrypted", "enclave"])
def test_pipeline_worker_fanout_all_modes(mode):
    """Stage.workers > 1 must fan chunks round-robin across the pool and
    still agree with the numpy oracle; per-worker counts are reported."""
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid], minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(carrier[valid],
                                              weights=delay[valid],
                                              minlength=20)
        return acc

    p = Pipeline([
        Stage("mapper", op="identity", workers=3),
        Stage("filter", op="delay_filter_u32", const=15, workers=2),
        Stage("reducer", op="custom", reduce_fn=reduce_fn,
              reduce_init={"count": np.zeros(20), "sum": np.zeros(20)}),
    ], SecureStreamConfig(mode=mode))
    src = (jnp.asarray(c) for c in flight_chunks(2048, 256, seed=3))
    out = p.run(src)
    cnt, s = _numpy_oracle()
    assert np.array_equal(out["count"], cnt)
    assert np.allclose(out["sum"], s)
    rep = p.report()
    # 8 chunks over 3 mapper workers round-robin: [3, 3, 2]
    assert rep["mapper"]["per_worker"] == [3, 3, 2]
    assert rep["filter"]["per_worker"] == [4, 4]
    assert sum(rep["mapper"]["per_worker"]) == rep["mapper"]["chunks"] == 8
    assert rep["mapper"]["mac_failures"] == 0


def test_scale_stage_carries_metrics_and_seed():
    """Rescaling must not reset the metrics trajectory or re-key edges."""
    p = _flight_pipeline("enclave")
    src = (jnp.asarray(c) for c in flight_chunks(1024, 256, seed=3))
    p.run(src)
    chunks_before = p.report()["mapper"]["chunks"]
    assert chunks_before == 4

    p2 = p.scale_stage("mapper", 4)
    assert p2.seed == p.seed
    # one trust domain: the directory (sessions, epoch, revocations) is
    # shared, so rescaling does not re-key the stream
    assert p2.directory is p.directory
    assert np.array_equal(p2.keys[0].key().key, p.keys[0].key().key)
    # carried forward, continuous trajectory...
    assert p2.report()["mapper"]["chunks"] == chunks_before
    src = (jnp.asarray(c) for c in flight_chunks(1024, 256, seed=4))
    out = p2.run(src)
    assert int(out["count"].sum()) > 0
    rep = p2.report()
    assert rep["mapper"]["chunks"] == chunks_before + 4
    assert len(rep["mapper"]["per_worker"]) == 4
    # ...while the original pipeline's metrics are not aliased
    assert p.report()["mapper"]["chunks"] == chunks_before


# ---------------------------------------------------- observable (tail)


def test_observable_from_array_keeps_tail():
    """A non-divisible source must emit the ragged tail, not drop rows."""
    x = jnp.arange(10, dtype=jnp.float32)
    seen = []
    (Observable.from_array(x, chunk_rows=4)
     .subscribe(on_next=lambda c: seen.append(np.asarray(c))))
    assert [c.shape[0] for c in seen] == [4, 4, 2]
    assert np.array_equal(np.concatenate(seen), np.asarray(x))
    total = (Observable.from_array(x, chunk_rows=4)
             .reduce(lambda acc, c, m: acc + float(jnp.sum(c)), init=0.0)
             .subscribe())
    assert total == float(x.sum())
