"""Property-based tests (hypothesis) for the crypto layer invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: use the fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.crypto import aead, chacha20, cwmac
from repro.crypto.keys import derive_stage_key, root_key_from_seed

SET = settings(max_examples=20, deadline=None)

keys8 = st.integers(0, 2 ** 32 - 1)


def _key(seed):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** 32, 8, dtype=np.uint32))


def _nonce(seed):
    return jnp.asarray(np.random.default_rng(seed + 77).integers(
        0, 2 ** 32, 3, dtype=np.uint32))


@SET
@given(st.integers(1, 2000), st.integers(0, 1000))
def test_seal_open_roundtrip(n, seed):
    key, nonce = _key(seed), _nonce(seed)
    pt = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** 32, n, dtype=np.uint32))
    ct, tag = aead.seal(key, nonce, pt)
    pt2, ok = aead.open_(key, nonce, ct, tag)
    assert bool(ok) and bool((pt2 == pt).all())
    # ciphertext differs from plaintext (overwhelming probability for n>=4)
    if n >= 4:
        assert not bool((ct == pt).all())


@SET
@given(st.integers(4, 500), st.integers(0, 200), st.integers(0, 10 ** 6))
def test_tamper_any_word_detected(n, seed, flip):
    key, nonce = _key(seed), _nonce(seed)
    pt = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** 32, n, dtype=np.uint32))
    ct, tag = aead.seal(key, nonce, pt)
    idx = flip % n
    ct_bad = ct.at[idx].set(ct[idx] ^ np.uint32(1 + (flip % 7)))
    _, ok = aead.open_(key, nonce, ct_bad, tag)
    assert not bool(ok)


@SET
@given(st.integers(1, 300), st.integers(0, 100))
def test_wrong_key_or_nonce_fails(n, seed):
    key, nonce = _key(seed), _nonce(seed)
    pt = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** 32, n, dtype=np.uint32))
    ct, tag = aead.seal(key, nonce, pt)
    _, ok1 = aead.open_(_key(seed + 1), nonce, ct, tag)
    _, ok2 = aead.open_(key, _nonce(seed + 1), ct, tag)
    assert not bool(ok1) and not bool(ok2)


@SET
@given(st.integers(1, 64), st.integers(0, 50),
       st.sampled_from(["float32", "bfloat16", "int32", "uint32", "float16"]))
def test_tensor_framing_roundtrip(rows, seed, dtype):
    shape = (rows, 3)
    if dtype in ("float32", "bfloat16", "float16"):
        x = jax.random.normal(jax.random.key(seed), shape).astype(dtype)
    else:
        x = jax.random.randint(jax.random.key(seed), shape, 0, 1000
                               ).astype(dtype)
    w, meta = aead.tensor_to_words(x)
    x2 = aead.words_to_tensor(w, meta)
    assert x2.dtype == x.dtype and x2.shape == x.shape
    assert bool((x2 == x).all())


@SET
@given(st.integers(1, 400), st.integers(1, 2 ** 31 - 2),
       st.integers(0, 2 ** 31 - 2), st.integers(0, 99))
def test_cwmac_matches_bigint_reference(n, r, s, seed):
    words = np.random.default_rng(seed).integers(0, 2 ** 32, n,
                                                 dtype=np.uint32)
    got = int(cwmac.mac(jnp.asarray(words), jnp.uint32(r), jnp.uint32(s)))
    assert got == cwmac.mac_reference(words, r, s)


@SET
@given(st.integers(0, 2 ** 31 - 2), st.integers(0, 2 ** 31 - 2))
def test_mulmod_matches_bigint(a, b):
    p = (1 << 31) - 1
    got = int(cwmac.mulmod(jnp.uint32(a), jnp.uint32(b)))
    assert got == (a * b) % p


def test_nonce_uniqueness_per_counter():
    k = derive_stage_key(root_key_from_seed(0), "edge0", 0)
    nonces = {tuple(k.nonce(i)) for i in range(1000)}
    assert len(nonces) == 1000


def test_keys_differ_per_stage():
    root = root_key_from_seed(0)
    k0 = derive_stage_key(root, "edge0", 0)
    k1 = derive_stage_key(root, "edge1", 1)
    assert not np.array_equal(k0.key, k1.key)
