"""Distribution layer: mesh-context rules, ZeRO shardings, PP schedule,
secure channels, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShardingConfig
from repro.dist.meshctx import MeshContext, local_mesh_context
from repro.launch import hloanalysis


def _ctx(shape=(1, 1), axes=("data", "model")):
    mesh = jax.make_mesh(shape, axes)
    return MeshContext(mesh=mesh, rules=dict(ShardingConfig().lookup()))


def test_spec_resolution_basics():
    ctx = _ctx()
    # 1-sized axes shard trivially
    assert ctx.spec_for(("batch", None, "embed"), (8, 4, 16)) == \
        P("data", None, None)


def test_spec_divisibility_fallback():
    ctx = _ctx()
    # strict: a dim of 3 cannot shard over axis of size 1? size-1 divides all
    assert ctx.spec_for(("vocab", "embed"), (3, 5), strict=True) == \
        P("model", None)


def test_spec_skips_missing_axes():
    ctx = _ctx()
    # "pod" axis not in this mesh: batch rule (pod, data) -> data only
    spec = ctx.spec_for(("batch",), (16,))
    assert spec == P("data")


def test_spec_no_double_axis_use():
    ctx = _ctx()
    rules = dict(ShardingConfig().with_rule("kv_seq", ("model",)).lookup())
    ctx.rules = rules
    # heads and kv_seq both want "model": first dim wins, second replicated
    spec = ctx.spec_for(("kv_seq", "heads"), (32, 32))
    assert spec in (P("model", None),)


def test_zero_sharding_of_opt_state():
    from repro.configs.base import OptimizerConfig
    from repro.models.layers import ParamSpec, abstract_from_template, \
        shardings_from_template
    from repro.optim import make_optimizer, opt_state_shardings
    ctx = _ctx()
    template = {"layers": {"w": ParamSpec((4, 8, 6), ("layers", "embed",
                                                      "mlp"))}}
    params_abs = abstract_from_template(template)
    p_shard = shardings_from_template(template, ctx)
    opt = make_optimizer(OptimizerConfig(name="adamw", zero_sharding=True))
    o_shard = opt_state_shardings(opt, params_abs, p_shard, ctx)
    m_spec = o_shard["m"]["layers"]["w"].spec
    # ZeRO: some previously-unsharded dim picked up the "data" axis
    assert "data" in [a for part in m_spec for a in
                      ((part,) if not isinstance(part, tuple) else part)
                      if a]


def test_hlo_analyzer_counts_scan_flops():
    import os
    sample = os.path.join("/tmp", "hlo_sample.txt")
    if not os.path.exists(sample):
        pytest.skip("sample HLO not present")
    a = hloanalysis.analyze(open(sample).read())
    assert abs(a.flops - 10 * 2 * 16 * 256 * 256) < 1e-3 * a.flops
    assert a.collective_bytes > 0


def test_hlo_shape_bytes():
    assert hloanalysis._shape_bytes("f32[4,8]{1,0}") == 128
    assert hloanalysis._shape_bytes("bf16[10]") == 20
    assert hloanalysis._shape_bytes("(f32[2], s32[3])") == 20
    assert hloanalysis._shape_bytes("pred[7]") == 7


def test_pp_pipeline_matches_sequential():
    """GPipe schedule over a 1-stage 'mesh' must equal direct application;
    on 1 device we can still exercise the schedule logic with S=1."""
    from repro.dist.pipeline_parallel import pipeline_apply
    mesh = jax.make_mesh((1,), ("stage",))
    W = jax.random.normal(jax.random.key(0), (1, 4, 4))  # (S=1 stage, ...)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    xs = jax.random.normal(jax.random.key(1), (3, 2, 4))  # (M, mb, d)
    out = pipeline_apply(stage_fn, W, xs, mesh)
    want = jnp.stack([stage_fn(W[0], xs[i]) for i in range(3)])
    assert float(jnp.abs(out - want).max()) < 1e-5


def test_secure_channel_roundtrip():
    from repro.attest.directory import ephemeral_edge_key
    from repro.core.secure_channel import protect, unprotect
    key = ephemeral_edge_key("pp", seed=1)
    x = jax.random.normal(jax.random.key(2), (4, 6), jnp.bfloat16)
    ct, tag, meta = protect(key, 5, x)
    y, ok = unprotect(key, 5, ct, tag, meta)
    assert bool(ok) and bool((y == x).all())
    # wrong step (nonce) fails
    _, ok2 = unprotect(key, 6, ct, tag, meta)
    assert not bool(ok2)


def test_optimizers_descend_quadratic():
    from repro.configs.base import OptimizerConfig
    from repro.optim import make_optimizer
    target = jnp.asarray([1.0, -2.0, 3.0])
    for name in ("adamw", "adafactor", "sgdm"):
        opt = make_optimizer(OptimizerConfig(name=name, lr=0.1,
                                             warmup_steps=0,
                                             weight_decay=0.0))
        params = {"w": jnp.zeros((3,), jnp.float32)}
        state = opt.init(params)
        loss0 = None
        for step in range(60):
            g = {"w": 2 * (params["w"] - target)}
            l = float(jnp.sum((params["w"] - target) ** 2))
            loss0 = l if loss0 is None else loss0
            params, state = opt.update(g, state, params,
                                       jnp.asarray(step, jnp.int32))
        assert float(jnp.sum((params["w"] - target) ** 2)) < loss0 * 0.5, name


# ----------------------------------------------------- dist collectives


def test_gpipe_schedule_structure():
    from repro.dist.pipeline_parallel import gpipe_schedule
    S, M = 3, 5
    ticks = gpipe_schedule(S, M)
    assert len(ticks) == M + S - 1
    seen = [su for tick in ticks for su in tick]
    assert sorted(seen) == [(s, m) for s in range(S) for m in range(M)]
    for t, tick in enumerate(ticks):
        for s, m in tick:
            assert m + s == t  # microbatch m occupies stage s at tick m+s


@pytest.mark.parametrize("seal", [False, True])
def test_pp_multistage_matches_sequential(seal):
    """3-stage GPipe with sealed boundaries == chaining the stages."""
    from repro.dist.pipeline_parallel import pipeline_apply
    S, M, mb, d = 3, 4, 2, 8
    W = jax.random.normal(jax.random.key(0), (S, d, d))
    xs = jax.random.normal(jax.random.key(1), (M, mb, d))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    out = pipeline_apply(stage_fn, W, xs, None, seal=seal)

    def chain(x):
        for s in range(S):
            x = stage_fn(W[s], x)
        return x

    want = jnp.stack([chain(xs[m]) for m in range(M)])
    assert float(jnp.abs(out - want).max()) < 1e-6


def test_pp_mesh_stage_axis_validated():
    from repro.dist.pipeline_parallel import pipeline_apply
    mesh = jax.make_mesh((1,), ("stage",))
    W = jnp.zeros((2, 4, 4))
    xs = jnp.zeros((3, 2, 4))
    # size-1 stage axis is fine for any S (host-driven schedule)
    pipeline_apply(lambda w, x: x @ w, W, xs, mesh)


def test_secure_exchange_roundtrip():
    from repro.attest.directory import ephemeral_edge_key
    from repro.dist.collectives import exchange, secure_exchange
    mesh = jax.make_mesh((1,), ("model",))
    W = 1
    x = jax.random.normal(jax.random.key(3), (W, W, 16, 4), jnp.float32)
    key = ephemeral_edge_key("shuffle", seed=0)
    y, ok = secure_exchange(x, mesh, "model", key=key, step=11)
    assert bool(ok.all())
    assert float(jnp.abs(y - jnp.swapaxes(x, 0, 1)).max()) == 0.0
    assert jnp.array_equal(exchange(x, mesh, "model"),
                           jnp.swapaxes(x, 0, 1))
    with pytest.raises(ValueError):
        secure_exchange(x.astype(jnp.bfloat16), mesh, "model", key=key,
                        step=0)
    with pytest.raises(ValueError):
        secure_exchange(x[0], mesh, "model", key=key, step=0)
    with pytest.raises(ValueError):  # omitting step would reuse nonces
        secure_exchange(x, mesh, "model", key=key)
