"""Documentation cannot rot: every fenced ``python`` block in docs/*.md
and README.md must execute.

Blocks run file by file in a shared namespace (notebook semantics — a
guide may build on its earlier snippets).  A block whose fence info
string contains ``skip`` (e.g. ```` ```python skip ````) is not
executed, but it must still *compile* — syntax errors fail either way.

The fence scanner itself is imported from ``scripts/check_docs.py`` (the
dependency-free CI syntax gate), so both gates share one definition of
"a fenced block".
"""
import importlib.util
import os
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

_spec = importlib.util.spec_from_file_location(
    "check_docs", ROOT / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def extract_fenced_blocks(path: Path):
    """-> [(lang, info, code, first_line_no)]; unterminated fences fail."""
    blocks, problems = check_docs.extract_fenced_blocks(path)
    assert not problems, problems
    return blocks


def python_blocks(path: Path):
    return [(i, c, ln) for (la, i, c, ln) in extract_fenced_blocks(path)
            if la == "python"]


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_python_blocks_compile(path):
    blocks = python_blocks(path)
    for info, code, ln in blocks:
        compile(code, f"{path.name}:{ln}", "exec")   # skip-marked included


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_python_blocks_execute(path):
    blocks = python_blocks(path)
    runnable = [(c, ln) for info, c, ln in blocks if "skip" not in info]
    if not runnable:
        pytest.skip(f"{path.name}: no runnable python blocks")
    ns = {"__name__": f"docs_example_{path.stem.replace('-', '_')}"}
    cwd = os.getcwd()
    os.chdir(ROOT)                       # docs examples may use repo paths
    try:
        for code, ln in runnable:
            try:
                exec(compile(code, f"{path.name}:{ln}", "exec"), ns)
            except Exception as e:
                raise AssertionError(
                    f"{path.name}: fenced block at line {ln} raised "
                    f"{type(e).__name__}: {e}") from e
    finally:
        os.chdir(cwd)


def test_docs_exist_and_are_linked_from_readme():
    """The six guides exist and README links to each of them."""
    readme = (ROOT / "README.md").read_text()
    for guide in ("architecture", "security-model", "dsl", "benchmarks",
                  "observability", "fault-tolerance"):
        assert (ROOT / "docs" / f"{guide}.md").is_file(), f"missing {guide}"
        assert f"docs/{guide}.md" in readme, f"README must link {guide}"
