"""repro.dsl: fluent builder + TOML spec loader, compiled to the engine.

The acceptance contract: the DelayedFlights pipeline expressed in <= 12
lines via the fluent DSL AND via a TOML spec, both bit-identical to the
hand-built ``Pipeline([Stage(...)])`` oracle in all three security modes
— including under ``rekey_every_n=3`` with a mid-stream revocation — and
structurally zero-overhead (the compiler emits the same Stage list the
hand-built form uses).  Plus: eager validation, bit-exact-only fusion
with reported decisions, and the spec-loader surface.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.attest.directory import KeyDirectory
from repro.configs.base import SecureStreamConfig
from repro.core import Pipeline, Stage
from repro.core.observable import describe_ops
from repro.data.synthetic import CARRIER_WORD, DELAY_WORD, flight_chunks
from repro.dsl import (DSLValidationError, SpecError, load_spec,
                       register_reducer, stream)

N_RECORDS, CHUNK = 1024, 64          # 16 chunks of 64 records (4 KiB each)


def _src(seed=1):
    return (jnp.asarray(c) for c in
            flight_chunks(N_RECORDS, CHUNK, seed=seed))


def _manual_reduce():
    """The pre-DSL hand-built reducer, kept verbatim as the oracle."""
    def reduce_fn(acc, chunk):
        carrier = np.asarray(chunk[:, CARRIER_WORD]).astype(np.int64)
        delay = np.asarray(chunk[:, DELAY_WORD]).astype(np.int64)
        valid = delay > 0
        acc["count"] = acc["count"] + np.bincount(carrier[valid],
                                                  minlength=20)
        acc["sum"] = acc["sum"] + np.bincount(
            carrier[valid], weights=delay[valid], minlength=20)
        return acc
    return reduce_fn, {"count": np.zeros(20), "sum": np.zeros(20)}


def _manual_pipeline(mode: str, workers: int = 2) -> Pipeline:
    """The pre-DSL construction (the parity oracle the DSL must match)."""
    fn, init = _manual_reduce()
    return Pipeline(
        [Stage("sgx_mapper", op="identity", workers=workers, sgx=True),
         Stage("sgx_filter", op="delay_filter_u32", const=15,
               workers=workers, sgx=True),
         Stage("reducer", op="custom", reduce_fn=fn, reduce_init=init)],
        SecureStreamConfig(mode=mode))


# The acceptance artifact: the whole job in <= 12 lines, fluent form.
FLUENT_FORM = """\
result = (stream(source)
          .map("identity", name="sgx_mapper", workers=2, sgx=True)
          .filter("delay_filter_u32", const=15, name="sgx_filter",
                  workers=2, sgx=True)
          .reduce("carrier_delay_stats", name="reducer")
          .run(mode=mode))
"""

# ... and the declarative TOML form (paper Listing 1 shape), 12 lines.
TOML_FORM = """\
mode = "MODE"
[stage.sgx_mapper]
op = "identity"
workers = 2
constraint = "sgx"
[stage.sgx_filter]
op = "delay_filter_u32"
const = 15
workers = 2
constraint = "sgx"
[stage.reducer]
reduce = "carrier_delay_stats"
"""


def _assert_same(a, b):
    assert np.array_equal(a["count"], b["count"])
    assert np.array_equal(a["sum"], b["sum"])


# ------------------------------------------------------- acceptance parity


@pytest.mark.parametrize("mode", ["plain", "encrypted", "enclave"])
def test_fluent_and_toml_bit_identical_to_manual(mode):
    """Both <= 12-line forms, bit-identical to the hand-built oracle."""
    assert len(FLUENT_FORM.strip().splitlines()) <= 12
    assert len(TOML_FORM.strip().splitlines()) <= 12

    oracle = _manual_pipeline(mode).run(_src())

    ns = {"stream": stream, "source": _src(), "mode": mode}
    exec(FLUENT_FORM, ns)                      # the documented snippet
    _assert_same(ns["result"], oracle)

    spec_out = load_spec(TOML_FORM.replace("MODE", mode)).run(_src())
    _assert_same(spec_out, oracle)


@pytest.mark.parametrize("mode", ["plain", "encrypted", "enclave"])
def test_parity_under_rekey_and_mid_stream_revocation(mode):
    """rekey_every_n=3 + a live revocation of a filter worker mid-stream:
    DSL-compiled and hand-built pipelines stay bit-identical."""
    def run(p):
        def source():
            for i, c in enumerate(flight_chunks(N_RECORDS, CHUNK, seed=1)):
                if i == 6:
                    p.directory.revoke(Pipeline.worker_id("sgx_filter", 1))
                yield jnp.asarray(c)
        return p.run(source(), rekey_every_n=3)

    manual = run(_manual_pipeline(mode))
    sb = (stream()
          .map("identity", name="sgx_mapper", workers=2, sgx=True)
          .filter("delay_filter_u32", const=15, name="sgx_filter",
                  workers=2, sgx=True)
          .reduce("carrier_delay_stats", name="reducer"))
    dsl = run(sb.build(mode))
    _assert_same(dsl, manual)
    # the revoked worker stopped receiving rows on the DSL pipeline too
    rep = sb.report()["sgx_filter"]
    assert rep["per_worker"][1] < rep["per_worker"][0]


def test_example_spec_file_loads_and_matches():
    """examples/flight_delay.toml is live documentation: it must load and
    agree with the fluent form."""
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "flight_delay.toml")
    sb = load_spec(path)
    out = sb.run(_src(), mode="encrypted")
    _assert_same(out, _manual_pipeline("encrypted").run(_src()))


def test_dsl_is_structurally_zero_overhead():
    """The compiler emits the same Stage list the hand-built form uses
    (modulo bit-exact fusion): with fusion off, stage tuples are equal —
    there is no DSL wrapper anywhere near the hot path."""
    sb = (stream()
          .map("identity", name="sgx_mapper", workers=2, sgx=True)
          .filter("delay_filter_u32", const=15, name="sgx_filter",
                  workers=2, sgx=True)
          .reduce("carrier_delay_stats", name="reducer").fuse(False))
    p = sb.build("encrypted")
    assert type(p) is Pipeline
    sig = [(s.name, s.op, s.const, s.workers, s.sgx) for s in p.stages]
    assert sig == [(s.name, s.op, s.const, s.workers, s.sgx)
                   for s in _manual_pipeline("encrypted").stages]


# ------------------------------------------------------------------ fusion


def test_identity_fusion_removes_a_hop_and_is_reported():
    sb = (stream()
          .map("identity", name="m")
          .filter("delay_filter_u32", const=15, name="f")
          .reduce("carrier_delay_stats", name="r"))
    p = sb.build("encrypted")
    assert [s.name for s in p.stages] == ["f", "r"]      # m absorbed
    rep = p.report()
    assert rep["f"]["fused_from"] == ["m"]
    assert any("fused" in d for d in rep["fusion"]["decisions"])
    # fusion survives a live rescale
    p2 = p.scale_stage("f", 3)
    assert p2.report()["f"]["fused_from"] == ["m"]


def test_fusion_declines_non_bit_exact_compositions():
    """scale∘scale is NOT fused (f32 rounding reorders); the declined
    decision is still reported."""
    sb = (stream().map("scale_f32", const=2.0, name="a")
          .map("scale_f32", const=3.0, name="b"))
    p = sb.build("encrypted")
    assert [s.name for s in p.stages] == ["a", "b"]
    assert any("kept 'a'|'b'" in d for d in p.fusion["decisions"])


def test_trailing_and_all_identity_chains():
    p = (stream().map("scale_f32", const=2.0, name="a")
         .map("identity", name="tail")).build("encrypted")
    assert [s.name for s in p.stages] == ["a"]
    assert p.fusion["fused_from"] == {"a": ["tail"]}
    p = (stream().map("identity", name="i0")
         .map("identity", name="i1")).build("encrypted")
    assert [s.name for s in p.stages] == ["i1"]
    assert p.fusion["fused_from"] == {"i1": ["i0"]}


def test_scale_pins_a_stage_against_fusion():
    sb = (stream().map("identity", name="m")
          .filter("delay_filter_u32", const=15, name="f")
          .scale("m", 4))
    p = sb.build("encrypted")
    assert [s.name for s in p.stages] == ["m", "f"]
    assert p.stages[0].workers == 4
    assert any("pinned" in d for d in p.fusion["decisions"])
    with pytest.raises(KeyError):
        stream().map("identity", name="m").scale("nope", 2)


def test_fused_output_matches_unfused():
    base = (stream()
            .map("identity", name="m")
            .filter("delay_filter_u32", const=15, name="f")
            .reduce("carrier_delay_stats", name="r"))
    fused, unfused = base, base.fuse(False)
    assert len(fused.build("encrypted").stages) \
        < len(unfused.build("encrypted").stages)
    _assert_same(fused.run(_src(), mode="encrypted"),
                 unfused.run(_src(), mode="encrypted"))


def test_worker_pool_identity_is_not_absorbed():
    """Fusion must not discard declared fan-out: an identity stage with
    an explicit worker pool survives, with the decision logged."""
    p = (stream().map("identity", name="m", workers=2)
         .filter("delay_filter_u32", const=15, name="f")).build("encrypted")
    assert [s.name for s in p.stages] == ["m", "f"]
    assert p.stages[0].workers == 2
    assert any("worker pool" in d for d in p.fusion["decisions"])
    # and the decline log never claims identity∘f is not bit-exact
    assert not any("identity∘" in d and "no bit-exact" in d
                   for d in p.fusion["decisions"])


def test_shared_builder_reruns_do_not_accumulate_reduce_state():
    """A mutable init passed to .reduce() must be copied per build:
    running a shared builder twice gives identical totals."""
    fn, init = _manual_reduce()
    sb = (stream().filter("delay_filter_u32", const=15, name="f")
          .reduce(fn, init, name="r"))
    first = sb.run(_src(), mode="plain")
    second = sb.run(_src(), mode="plain")
    _assert_same(first, second)


# -------------------------------------------------------- eager validation


def test_unknown_op_rejected_at_build():
    with pytest.raises(DSLValidationError, match="registered ops"):
        stream().map("not_an_op").build("encrypted")


def test_closure_under_enclave_rejected_eagerly_unless_unconstrained():
    sb = stream().map(lambda x: x * 2, name="c")
    with pytest.raises(DSLValidationError, match="no-dynamic-linking"):
        sb.build("enclave")
    # sgx=False runs on the encrypted (non-enclave) path: allowed
    out = (stream().map(lambda x: x * 2.0, name="c", sgx=False)
           .build("enclave")
           .run(iter([jnp.ones(64, jnp.float32)])))
    assert np.allclose(np.asarray(out), 2.0)


def test_structural_validation():
    with pytest.raises(DSLValidationError, match="empty pipeline"):
        stream().build("plain")
    with pytest.raises(DSLValidationError, match="terminal"):
        (stream().reduce("sum", name="r")
         .map("identity", name="m")).build("plain")
    with pytest.raises(DSLValidationError, match="duplicate"):
        (stream().map("identity", name="x")
         .map("identity", name="x")).build("plain")
    with pytest.raises(DSLValidationError, match="workers"):
        stream().map("identity", workers=0).build("plain")
    with pytest.raises(KeyError, match="unknown reducer"):
        stream().map("identity").reduce("nope").build("plain")
    with pytest.raises(DSLValidationError, match="unknown mode"):
        stream().map("identity").build("tls")


def test_rekey_cadence_rejected_at_build_not_midstream():
    """The rekey-vs-epoch-history guard fires at build() — before any
    chunk is sealed — with the engine's own error message."""
    sb = (stream().map("scale_f32", const=2.0, name="s")
          .directory(KeyDirectory(epoch_history=1)))
    with pytest.raises(ValueError, match="epoch_history"):
        sb.build("encrypted", rekey_every_n=1)


# ------------------------------------------------------------- spec loader


def test_spec_dict_and_array_forms_and_count_alias():
    doc = {"mode": "plain",
           "stage": [{"name": "f", "op": "delay_filter_u32", "const": 15,
                      "count": 2, "constraint": "type==sgx"},
                     {"name": "r", "reduce": "carrier_delay_stats"}]}
    sb = load_spec(doc)
    p = sb.build()
    assert p.stages[0].workers == 2 and p.stages[0].sgx
    _assert_same(sb.run(_src()),
                 load_spec(TOML_FORM.replace("MODE", "plain")).run(_src()))


def test_spec_local_reducers_and_errors():
    out = load_spec(
        {"mode": "plain",
         "stage": [{"name": "r", "reduce": "n_chunks"}]},
        reducers={"n_chunks": ((lambda acc, c: acc + 1), 0)},
    ).run(_src())
    assert out == N_RECORDS // CHUNK

    with pytest.raises(SpecError, match="no stages"):
        load_spec({"mode": "plain"})
    with pytest.raises(SpecError, match="'op'.*or a 'reduce'|needs"):
        load_spec({"stage": [{"name": "x"}]})
    with pytest.raises(SpecError, match="missing a name"):
        load_spec({"stage": [{"op": "identity"}]})
    with pytest.raises(SpecError, match="cannot parse"):
        load_spec("stage = ???\n")


def test_spec_rejects_unknown_keys():
    """A typo'd key must fail the load, not run with a silent default."""
    with pytest.raises(SpecError, match="unknown key 'conts'"):
        load_spec({"stage": [{"name": "f", "op": "delay_filter_u32",
                              "conts": 15}]})
    with pytest.raises(SpecError, match="unknown key 'worker'"):
        load_spec({"stage": [{"name": "f", "op": "identity",
                              "worker": 2}]})
    with pytest.raises(SpecError, match="unknown top-level key"):
        load_spec({"mod": "plain",
                   "stage": [{"name": "f", "op": "identity"}]})
    with pytest.raises(SpecError, match=r"unknown \[pipeline\] key"):
        load_spec({"pipeline": {"mode": "plain", "rekey": 3},
                   "stage": [{"name": "f", "op": "identity"}]})


def test_mini_toml_parser_subset():
    from repro.dsl.spec import parse_toml
    doc = parse_toml("""
    # comment
    name = "x"            # trailing comment
    n = 3
    f = 1.5
    flag = true
    [a.b]
    k = 'single'
    [[arr]]
    v = 1
    [[arr]]
    v = 2
    """)
    assert doc["name"] == "x" and doc["n"] == 3 and doc["f"] == 1.5
    assert doc["flag"] is True and doc["a"]["b"]["k"] == "single"
    assert [t["v"] for t in doc["arr"]] == [1, 2]


def test_registered_reducer_roundtrip():
    @register_reducer("test_dsl_total_delay")
    def _total(**kw):
        def fn(acc, chunk):
            return acc + int(np.asarray(chunk[:, DELAY_WORD]).sum())
        return fn, 0
    out = (stream(_src()).reduce("test_dsl_total_delay").run(mode="plain"))
    assert out > 0


# -------------------------------------------------- observable interop


def test_as_observable_matches_plain_mode():
    """The DSL chain lowered onto the plaintext Observable layer is the
    cleartext oracle: identical result to mode='plain'."""
    sb = (stream()
          .map("identity", name="m")
          .filter("delay_filter_u32", const=15, name="f")
          .reduce("carrier_delay_stats", name="r"))
    _assert_same(sb.as_observable(_src()).subscribe(),
                 sb.run(_src(), mode="plain"))


def test_shared_describe_vocabulary():
    sb = (stream().map("identity", name="m", workers=4)
          .filter("delay_filter_u32", const=15, name="f"))
    d = sb.describe()
    assert "map(identity)[w=4,sgx]" in d and "filter(delay_filter_u32)" in d
    assert describe_ops(sb.ops) == d
    assert "map" in sb.as_observable(_src()).describe()
