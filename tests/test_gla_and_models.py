"""Model-component correctness: chunked GLA vs sequential oracle (property),
MoE dispatch invariants, flash attention equivalence with model layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: use the fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_model_config, reduce_for_smoke
from repro.dist.meshctx import local_mesh_context
from repro.models.gla import chunked_gla, gla_decode_step, gla_reference
from repro.models.moe import _capacity, moe_ffn, moe_template
from repro.models.layers import init_from_template

SET = settings(max_examples=12, deadline=None)


@SET
@given(st.integers(0, 50), st.sampled_from([8, 16, 32]),
       st.booleans(), st.sampled_from([4, 8, 16]))
def test_chunked_gla_matches_sequential(seed, S, normalize, chunk):
    B, H, Dk, Dv = 2, 2, 4, 6
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    log_f = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    i_g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, S, H)))
    ref = gla_reference(q, k, v, log_f, i_g, normalize=normalize)
    out = chunked_gla(q, k, v, log_f, i_g, chunk=min(chunk, S),
                      normalize=normalize)
    assert float(jnp.abs(out - ref).max()) < 1e-3


@SET
@given(st.integers(0, 30))
def test_gla_streaming_state_continuation(seed):
    """chunked_gla(return_state) + decode steps == one long chunked_gla."""
    B, S, H, Dk, Dv = 1, 16, 2, 4, 4
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, S + 4, H, Dk))
    k = jax.random.normal(ks[1], (B, S + 4, H, Dk))
    v = jax.random.normal(ks[2], (B, S + 4, H, Dv))
    log_f = -jax.nn.softplus(jax.random.normal(ks[3], (B, S + 4, H)))
    i_g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, S + 4, H)))
    full = gla_reference(q, k, v, log_f, i_g)
    _, state = chunked_gla(q[:, :S], k[:, :S], v[:, :S], log_f[:, :S],
                           i_g[:, :S], chunk=8, return_state=True)
    outs = []
    for t in range(S, S + 4):
        y, state = gla_decode_step(q[:, t], k[:, t], v[:, t], log_f[:, t],
                                   i_g[:, t], state)
        outs.append(y)
    got = jnp.stack(outs, axis=1)
    assert float(jnp.abs(got - full[:, S:]).max()) < 1e-3


# ------------------------------------------------------------------- MoE


def test_moe_capacity_formula():
    assert _capacity(1000, 2, 8, 1.25) % 8 == 0
    assert _capacity(1000, 2, 8, 1.25) >= 1000 * 2 / 8


@SET
@given(st.integers(0, 20))
def test_moe_outputs_finite_and_router_normalized(seed):
    ctx = local_mesh_context()
    cfg = reduce_for_smoke(get_model_config("moonshot-v1-16b-a3b"))
    t = moe_template(cfg)
    p = init_from_template(t, jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 1), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    out, aux = moe_ffn(p, x, cfg, ctx)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) > 0.5  # balance loss ~1 for near-uniform routing


def test_moe_capacity_overflow_drops_not_corrupts():
    """With capacity_factor near 0, output shrinks toward 0 but stays finite."""
    import dataclasses
    ctx = local_mesh_context()
    cfg = reduce_for_smoke(get_model_config("moonshot-v1-16b-a3b"))
    cfg_low = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    t = moe_template(cfg_low)
    p = init_from_template(t, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    out, _ = moe_ffn(p, x, cfg_low, ctx)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    full_t = moe_template(cfg)
    out_full, _ = moe_ffn(init_from_template(full_t, jax.random.key(0)),
                          x, cfg, ctx)
    # dropped tokens -> strictly less output energy
    assert float(jnp.abs(out.astype(jnp.float32)).sum()) <= \
        float(jnp.abs(out_full.astype(jnp.float32)).sum()) + 1e-3


# ------------------------------------------------------- mamba2 / xlstm


def test_mamba2_prefill_decode_continuation(ctx):
    from repro.models import mamba2 as M2
    cfg = reduce_for_smoke(get_model_config("zamba2-1.2b"))
    t = M2.mamba2_template(cfg)
    p = init_from_template(t, jax.random.key(0))
    B, S = 2, 24
    x = jax.random.normal(jax.random.key(1), (B, S + 8, cfg.d_model),
                          jnp.bfloat16)
    full = M2.mamba2_forward(p, x, cfg, ctx, chunk=8)
    y0, cache = M2.mamba2_forward_with_state(p, x[:, :S], cfg, ctx, chunk=8)
    outs = []
    for tstep in range(S, S + 8):
        y, cache = M2.mamba2_decode(p, x[:, tstep:tstep + 1], cache, cfg, ctx)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1).astype(jnp.float32)
    want = full[:, S:].astype(jnp.float32)
    err = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-6))
    assert err < 0.05, err


def test_xlstm_prefill_decode_continuation(ctx):
    from repro.models import xlstm as XL
    cfg = reduce_for_smoke(get_model_config("xlstm-125m"))
    t = XL.mlstm_template(cfg)
    p = init_from_template(t, jax.random.key(0))
    B, S = 2, 16
    x = jax.random.normal(jax.random.key(1), (B, S + 16, cfg.d_model),
                          jnp.bfloat16)
    full = XL.mlstm_forward(p, x, cfg, ctx)
    _, state = XL.mlstm_forward_with_state(p, x[:, :S], cfg, ctx)
    outs = []
    for tstep in range(S, S + 16):
        y, state = XL.mlstm_decode(p, x[:, tstep:tstep + 1], state, cfg, ctx)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1).astype(jnp.float32)
    want = full[:, S:].astype(jnp.float32)
    err = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-6))
    assert err < 0.05, err
