"""Per-kernel allclose sweeps: every Pallas kernel (interpret=True on CPU)
against its ref.py pure-jnp oracle, over shapes and configurations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.crypto import chacha20 as cc
from repro.crypto.cwmac import mac as mac_jnp, mac_reference
from repro.kernels.chacha20.chacha20 import chacha20_xor_blocks
from repro.kernels.chacha20.ref import chacha20_xor_blocks_ref, \
    chacha20_xor_rows_ref
from repro.kernels.chacha20 import ops as chacha_ops
from repro.kernels.cwmac import ops as mac_ops
from repro.kernels.enclave_map import ops as enclave_ops
from repro.kernels.enclave_map.ref import enclave_apply_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref

rng = np.random.default_rng(42)
KEY = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
KEY2 = jnp.asarray(rng.integers(0, 2 ** 32, 8, dtype=np.uint32))
NONCE = jnp.asarray(rng.integers(0, 2 ** 32, 3, dtype=np.uint32))


# ---------------------------------------------------------------- chacha20


@pytest.mark.parametrize("n_blocks,block_rows", [(256, 64), (512, 512),
                                                 (1024, 128)])
def test_chacha20_kernel_matches_ref(n_blocks, block_rows):
    data = jnp.asarray(rng.integers(0, 2 ** 32, (n_blocks, 16),
                                    dtype=np.uint32))
    out_k = chacha20_xor_blocks(KEY, NONCE, 1, data, block_rows=block_rows)
    out_r = chacha20_xor_blocks_ref(KEY, NONCE, 1, data)
    assert bool((out_k == out_r).all())


@pytest.mark.parametrize("n_words", [1, 15, 16, 17, 1000, 8192])
def test_chacha20_flat_involution(n_words):
    w = jnp.asarray(rng.integers(0, 2 ** 32, n_words, dtype=np.uint32))
    ct = chacha_ops.encrypt_words(KEY, NONCE, w)
    assert bool((chacha_ops.decrypt_words(KEY, NONCE, ct) == w).all())
    assert bool((ct == cc.encrypt_words(KEY, NONCE, w)).all())


def test_chacha20_rows_kernel_matches_ref():
    """Per-row (key, nonce, counter) kernel — the AEAD fast-path cipher."""
    R = 96
    keys = jnp.asarray(rng.integers(0, 2 ** 32, (R, 8), dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2 ** 32, (R, 3), dtype=np.uint32))
    counters = jnp.asarray(rng.integers(0, 2 ** 32, R, dtype=np.uint32))
    data = jnp.asarray(rng.integers(0, 2 ** 32, (R, 16), dtype=np.uint32))
    out_k = chacha_ops.xor_rows(keys, nonces, counters, data, block_rows=32)
    out_r = chacha20_xor_rows_ref(keys, nonces, counters, data)
    assert bool((out_k == out_r).all())
    # shared-key form must equal explicit per-row broadcast
    out_s = chacha_ops.xor_rows(KEY, nonces, counters, data, block_rows=32)
    out_sr = chacha20_xor_rows_ref(KEY, nonces, counters, data)
    assert bool((out_s == out_sr).all())


def test_chacha20_rfc7539_block():
    key = np.array([0x03020100, 0x07060504, 0x0b0a0908, 0x0f0e0d0c,
                    0x13121110, 0x17161514, 0x1b1a1918, 0x1f1e1d1c],
                   dtype=np.uint32)
    nonce = np.array([0x09000000, 0x4a000000, 0x00000000], dtype=np.uint32)
    blk = cc.chacha20_block(jnp.asarray(key), jnp.asarray(nonce),
                            jnp.asarray([1], jnp.uint32))[0]
    expected = np.array([0xe4e7f110, 0x15593bd1, 0x1fdd0f50, 0xc47120a3,
                         0xc7f4d1c7, 0x0368c033, 0x9aaa2204, 0x4e6cd4c3,
                         0x466482d2, 0x09aa9f07, 0x05d7c214, 0xa2028bd9,
                         0xd19c12b5, 0xb94e16de, 0xe883d0cb, 0x4e3c50a2],
                        dtype=np.uint32)
    assert np.array_equal(np.asarray(blk), expected)


# ------------------------------------------------------------- enclave_map


@pytest.mark.parametrize("op,const", [("identity", 0.0), ("scale_f32", 2.5),
                                      ("relu_f32", 0.0), ("square_f32", 0.0),
                                      ("threshold_mask", 0.25),
                                      ("delay_filter_u32", 15)])
@pytest.mark.parametrize("rows", [256, 512])
def test_enclave_map_matches_ref(op, const, rows):
    pt = rng.standard_normal(rows * 16).astype(np.float32)
    ct = cc.encrypt_words(KEY, NONCE, jnp.asarray(pt.view(np.uint32)))
    blocks = ct.reshape(-1, 16)
    out_k = enclave_ops.enclave_map(KEY, KEY2, NONCE, 1, blocks, op=op,
                                    const=const, block_rows=256)
    out_r = enclave_apply_ref(KEY, KEY2, NONCE, 1, blocks, op=op, const=const)
    assert bool((out_k == out_r).all()), op


def test_enclave_map_semantics_scale():
    pt = rng.standard_normal(512 * 16).astype(np.float32)
    ct = cc.encrypt_words(KEY, NONCE, jnp.asarray(pt.view(np.uint32)))
    out = enclave_ops.enclave_map(KEY, KEY2, NONCE, 1, ct.reshape(-1, 16),
                                  op="scale_f32", const=3.0, block_rows=256)
    dec = cc.decrypt_words(KEY2, NONCE, out.reshape(-1))
    assert np.allclose(np.asarray(dec).view(np.float32), pt * 3.0)


# ------------------------------------------------------------------- cwmac


@pytest.mark.parametrize("n_words", [100, 1024, 5000])
@pytest.mark.parametrize("tile", [256, 1024])
def test_cwmac_kernel_matches_oracles(n_words, tile):
    words = jnp.asarray(rng.integers(0, 2 ** 32, n_words, dtype=np.uint32))
    r = jnp.uint32(0x12345678 & 0x7FFFFFFE)
    s = jnp.uint32(0x23456789 & 0x7FFFFFFE)
    t_k = int(mac_ops.mac(words, r, s, tile=tile))
    t_j = int(mac_jnp(words, r, s))
    t_h = mac_reference(np.asarray(words), int(r), int(s))
    assert t_k == t_j == t_h


# --------------------------------------------------------- flash attention


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,qc,kc", [(128, 64, 64), (256, 64, 32),
                                     (256, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_ref(causal, S, qc, kc, dtype):
    B, H, D = 2, 2, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), dtype)
    o1 = flash_attention_bhsd(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    o2 = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(o1.astype(jnp.float32)
                         - o2.astype(jnp.float32)).max()) < tol


def test_flash_custom_vjp_matches_naive_grads():
    from repro.models.flash import flash_attention as flash_jnp
    B, S, H, D = 2, 128, 2, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)

    def naive(q, k, v):
        s = jnp.einsum("BqHD,BkHD->BHqk", q, k) / np.sqrt(D)
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("BHqk,BkHD->BqHD", p, v)

    g1 = jax.grad(lambda a, b, c: jnp.sum(flash_jnp(a, b, c, True, 32, 64) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda a, b, c: jnp.sum(naive(a, b, c) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.abs(a - b).max()) < 1e-4
