"""Live pipeline health (PR 8 acceptance): PipelineMonitor sliding-window
stage stats, Prometheus/JSON exporters + HTTP scrape endpoint, SLO/stall
Watchdog, and per-hop dispatch accounting.

The acceptance runs mirror test_obs's 8-stage jobs: the dispatch-count
regression gate pins compiled-program launches per stage hop for the
8-stage encrypted (and enclave) window job, a deliberately induced stall
and an injected mac-failure burst each trip the watchdog EXACTLY once
with the matching ``stall``/``slo_breach`` audit event, and output is
bit-identical with monitoring on vs off on the rekey+revocation job.
"""
import dataclasses
import importlib.util
import json
import pathlib
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (Histogram, MetricsRegistry, NULL_MONITOR,
                       PipelineMonitor, REGISTRY, SLORule, Tracer, Watchdog,
                       dispatch_count, prometheus_text, reset_dispatch_count,
                       serve_metrics, snapshot_json)
from repro.obs.audit import AuditLog

ROOT = pathlib.Path(__file__).resolve().parents[1]
_spec = importlib.util.spec_from_file_location(
    "check_prometheus", ROOT / "scripts" / "check_prometheus.py")
check_prometheus = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_prometheus)


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------- histogram edge cases (satellite)


def test_histogram_empty_and_one_sample_percentiles():
    h = Histogram("h")
    assert h.percentile(0) is None and h.percentile(100) is None
    assert h.mean is None
    assert h.summary() == {"count": 0, "mean": None, "p50": None,
                           "p95": None, "p99": None, "max": None}
    h.observe(3.5)
    # one sample: every percentile IS that sample
    for q in (0, 50, 95, 99, 100):
        assert h.percentile(q) == 3.5
    assert h.mean == 3.5 and h.summary()["max"] == 3.5
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        h.percentile(-0.5)


def test_histogram_eviction_keeps_percentiles_exact():
    """Past max_samples the OLDEST sample drops; percentiles stay exact
    over the retained suffix — including with duplicate values."""
    h = Histogram("h", max_samples=4)
    for v in (5.0, 1.0, 5.0, 3.0):
        h.observe(v)
    h.observe(2.0)                 # evicts the first 5.0, NOT the second
    assert sorted(h._sorted) == [1.0, 2.0, 3.0, 5.0]
    assert h.percentile(0) == 1.0 and h.percentile(100) == 5.0
    assert h.count == 5            # lifetime count unaffected by eviction
    h.observe(0.5)                 # evicts the 1.0
    assert sorted(h._sorted) == [0.5, 2.0, 3.0, 5.0]
    assert h.percentile(0) == 0.5
    # retained window is exactly the last max_samples arrivals
    assert h._order == [5.0, 3.0, 2.0, 0.5]


def test_registry_reset_prefix_selectivity():
    r = MetricsRegistry()
    r.counter("a.x").inc(3)
    r.counter("a.y").inc(4)
    r.gauge("b.x").set(7)
    r.histogram("a.h").observe(1.0)
    r.reset(prefix="a.")
    assert r.counter("a.x").value == 0 and r.counter("a.y").value == 0
    assert r.histogram("a.h").count == 0
    assert r.gauge("b.x").value == 7          # untouched: prefix mismatch
    r.reset()                                  # empty prefix = everything
    assert r.gauge("b.x").value == 0


# ------------------------------------------ chrome counter events (satellite)


def test_tracer_counter_events_export_as_chrome_C(tmp_path):
    tr = Tracer()
    with tr.span("work", track="s0"):
        tr.counter("queue_rows", 16, track="s0")
        tr.counter("queue_rows", 8, track="s0")
    tr.counter("windows_per_s", 12.5, track="s1")
    doc = tr.export_chrome(str(tmp_path / "trace.json"))
    loaded = json.loads((tmp_path / "trace.json").read_text())
    assert loaded == doc
    cs = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert len(cs) == 3
    q = [e for e in cs if e["name"] == "queue_rows"]
    assert [e["args"]["value"] for e in q] == [16.0, 8.0]
    # counters land on their track's tid (same lane as the spans)
    span_ev = next(e for e in doc["traceEvents"]
                   if e["ph"] == "X" and e["name"] == "work")
    assert all(e["tid"] == span_ev["tid"] for e in q)
    w = next(e for e in cs if e["name"] == "windows_per_s")
    assert w["args"]["value"] == 12.5 and w["tid"] != span_ev["tid"]
    # timestamps are monotone non-decreasing within a track
    assert q[0]["ts"] <= q[1]["ts"]


def test_null_tracer_counter_is_noop():
    from repro.obs import NULL_TRACER
    assert NULL_TRACER.counter("anything", 1.0) is None


# ------------------------------------------------------- monitor unit tests


def test_monitor_sliding_window_evicts_by_time():
    clk = FakeClock()
    mon = PipelineMonitor(window_seconds=10.0, clock=clk)
    for _ in range(4):
        mon.record_window("s0", rows=8, bytes=800, seconds=0.1)
        clk.advance(1.0)
    st = mon.stage_stats("s0")
    assert st["windows"] == 4 and st["windows_total"] == 4
    assert st["rows_per_s"] == pytest.approx(32 / 4.0)   # span = elapsed 4s
    clk.advance(20.0)                   # everything slides out of horizon
    st = mon.stage_stats("s0")
    assert st["windows"] == 0 and st["windows_total"] == 4
    assert st["rows_per_s"] == 0.0 and st["p95_s"] is None


def test_monitor_worker_skew_and_failure_rate():
    clk = FakeClock()
    mon = PipelineMonitor(clock=clk)
    mon.record_window("s0", rows=8, ok_rows=6, seconds=0.1,
                      worker_rows={0: 6, 1: 2})
    st = mon.stage_stats("s0")
    assert st["worker_rows"] == {0: 6, 1: 2}
    assert st["worker_skew"] == pytest.approx(6 / 4.0)   # max/mean
    assert st["mac_failures"] == 2
    assert st["mac_failure_rate"] == pytest.approx(0.25)
    assert mon.stage_stats("nope") is None


def test_monitor_audit_rates_are_timestamped_on_ingest():
    clk = FakeClock()
    mon = PipelineMonitor(window_seconds=10.0, clock=clk)
    log = AuditLog()

    class Dir:
        epoch = 3
        audit = log

    class P:
        directory = Dir()

    mon.attach(P())
    log.record("rekey", epoch=1)
    log.record("rekey", epoch=2)
    clk.advance(5.0)
    mon.record_window("s0", rows=1, seconds=0.01, min_epoch=1)
    snap = mon.snapshot()
    assert snap["pipeline"]["rekey_per_s"] == pytest.approx(2 / 5.0)
    assert snap["stages"]["s0"]["epoch_lag"] == 2        # 3 - 1
    clk.advance(30.0)                   # rekey stamps slide out
    assert "rekey_per_s" not in mon.snapshot()["pipeline"]


def test_null_monitor_is_inert():
    assert NULL_MONITOR.enabled is False
    NULL_MONITOR.record_window("s", rows=1)
    assert NULL_MONITOR.snapshot()["stages"] == {}


# ------------------------------------------------------------------ watchdog


def test_watchdog_stall_trips_exactly_once_with_audit_event():
    clk = FakeClock()
    mon = PipelineMonitor(clock=clk)
    log = AuditLog()
    fired = []
    wd = Watchdog(mon, [SLORule("no-stall", stall_seconds=5.0)],
                  on_breach=[fired.append], audit=log)
    mon.record_window("s0", rows=8, seconds=0.01)
    assert mon.check() == [] and fired == []
    clk.advance(6.0)                    # deliberately induced stall
    breaches = mon.check()
    assert [b.rule for b in breaches] == ["no-stall"]
    assert breaches[0].kind == "stall"
    clk.advance(6.0)
    assert mon.check() == []            # latched: trips EXACTLY once
    assert len(fired) == 1
    events = log.events("stall")
    assert len(events) == 1             # the matching audit event
    assert events[0].detail["rule"] == "no-stall"
    assert events[0].detail["metric"] == "last_progress_age_s"
    assert wd.breached() == ["no-stall"]
    # progress recovers the rule; a fresh stall re-fires
    mon.record_window("s0", rows=8, seconds=0.01)
    assert wd.breached() == []
    clk.advance(6.0)
    assert [b.rule for b in mon.check()] == ["no-stall"]
    assert len(log.events("stall")) == 2


def test_watchdog_rule_limits_and_callback_order():
    clk = FakeClock()
    mon = PipelineMonitor(window_seconds=10.0, clock=clk)
    order = []
    wd = Watchdog(mon, [
        SLORule("latency", stage="s0", max_p95_seconds=0.5),
        SLORule("throughput", stage="s0", min_windows_per_s=0.01),
    ], on_breach=[lambda b: order.append(("first", b.rule)),
                  lambda b: order.append(("second", b.rule))],
        audit=AuditLog())
    clk.advance(1.0)
    mon.record_window("s0", rows=8, seconds=2.0)    # p95 breach
    assert order == [("first", "latency"), ("second", "latency")]
    b = wd.fired[0]
    assert b.metric == "p95_s" and b.value == 2.0 and b.limit == 0.5
    assert b.stage == "s0"
    # unattached-stage rules never fire before data exists
    wd2 = Watchdog(mon, [SLORule("ghost", stage="zzz", min_mbps=1e9)],
                   audit=AuditLog())
    assert wd2.check() == []


def test_watchdog_unattached_fallback_audit_log():
    mon = PipelineMonitor(clock=FakeClock())
    wd = Watchdog(mon, [SLORule("r", stall_seconds=1.0)])
    assert isinstance(wd.audit, AuditLog)


# ----------------------------------------------------------------- exporters


def _loaded_monitor():
    clk = FakeClock()
    mon = PipelineMonitor(clock=clk)
    clk.advance(2.0)
    mon.record_window("s0", rows=8, bytes=2048, seconds=0.01,
                      queue_rows=8, worker_rows={0: 5, 1: 3})
    mon.record_window("ingress", rows=8, bytes=2048, seconds=0.002,
                      dispatches=1)
    return mon


def test_prometheus_text_is_wellformed_with_stage_series():
    reg = MetricsRegistry()
    reg.counter("pipeline.host_syncs").inc(4)
    reg.counter("device.dispatches").inc(9)
    reg.histogram("pipeline.stage.s0.window_seconds").observe(0.01)
    reg.gauge("pipeline.stage.s0.queue_rows").set(8)
    text = prometheus_text(reg, _loaded_monitor())
    problems = check_prometheus.validate(
        text, require_labels=(("stage", "s0"), ("stage", "ingress")),
        min_samples=10)
    assert problems == [], "\n".join(problems)
    assert 'repro_stage_windows_per_second{stage="s0"}' in text
    assert 'repro_pipeline_stage_window_seconds{stage="s0",quantile="0.5"}' \
        in text
    assert "repro_pipeline_host_syncs 4" in text
    assert "repro_device_dispatches 9" in text


def test_prometheus_text_escapes_label_values():
    mon = PipelineMonitor(clock=FakeClock())
    mon.record_window('we"ird\\st\nage', rows=1, seconds=0.01)
    text = prometheus_text(MetricsRegistry(), mon)
    assert check_prometheus.validate(text) == []
    assert '\\"' in text and "\\\\" in text


def test_snapshot_json_is_json_serializable():
    doc = snapshot_json(_loaded_monitor(), MetricsRegistry())
    rt = json.loads(json.dumps(doc))
    assert rt["monitor"]["stages"]["s0"]["windows"] == 1
    assert rt["monitor"]["pipeline"]["windows_total"] == 2


def test_http_endpoints_serve_metrics_health_snapshot():
    mon = _loaded_monitor()
    Watchdog(mon, [SLORule("q", stage="s0", max_queue_rows=4)],
             audit=AuditLog())
    with serve_metrics(0, monitor=mon) as srv:
        assert srv.port != 0
        body = urllib.request.urlopen(srv.url + "/metrics").read().decode()
        assert check_prometheus.validate(body) == []
        assert 'stage="s0"' in body
        health = json.load(urllib.request.urlopen(srv.url + "/health"))
        assert health["status"] == "degraded"       # queue 8 > limit 4
        assert health["breached"] == ["q"]
        snap = json.load(urllib.request.urlopen(srv.url + "/snapshot"))
        assert snap["monitor"]["watchdog"]["breached"] == ["q"]
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(srv.url + "/nope")


# ------------------------------------------------- engine integration (e2e)


def _src(n=9):
    return [jnp.asarray(np.random.default_rng(i).standard_normal(
        (64,)).astype(np.float32)) for i in range(n)]


def _linear8(mode, wc=8):
    from repro.attest.directory import KeyDirectory
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline, Stage
    stages = [Stage(f"s{i}", op="scale_f32", const=1.0 + 0.125 * i)
              for i in range(8)]
    return Pipeline(stages, SecureStreamConfig(mode=mode),
                    directory=KeyDirectory(seed=0), window_chunks=wc)


def test_dispatch_gate_8stage_encrypted_window_job():
    """THE per-hop dispatch-count regression gate (ROADMAP megakernel
    item): the 8-stage encrypted window job costs exactly 2 launches per
    stage window (open_many + seal_many), 1 per ingress window
    (seal_many) and 1 per egress window (open_many).  A fused megakernel
    must DROP these numbers; a regression to per-chunk dispatching would
    multiply them by the window size."""
    reset_dispatch_count()
    p = _linear8("encrypted")
    src = _src(8)                       # exactly one 8-chunk window
    got = []
    p.run(iter(src), on_result=lambda r: got.append(np.asarray(r)))
    assert len(got) == 8
    rep = p.report()
    for i in range(8):
        assert rep[f"s{i}"]["windows"] == 1
        assert rep[f"s{i}"]["dispatches"] == 2
        assert rep[f"s{i}"]["dispatches_per_window"] == 2.0
    assert rep["dispatch"]["ingress"] == {"windows": 1, "dispatches": 1}
    assert rep["dispatch"]["egress"] == {"windows": 1, "dispatches": 1}
    assert rep["dispatch"]["total"] == 8 * 2 + 1 + 1
    assert dispatch_count() == rep["dispatch"]["total"]


def test_dispatch_gate_8stage_enclave_window_job():
    """Enclave hops pin at 5 launches per stage window: mac-key derive +
    ciphertext MAC on the way in, the fused enclave_map_rows program,
    and mac-key derive + re-MAC on the way out."""
    reset_dispatch_count()
    p = _linear8("enclave")
    src = _src(8)
    got = []
    p.run(iter(src), on_result=lambda r: got.append(np.asarray(r)))
    assert len(got) == 8
    rep = p.report()
    for i in range(8):
        assert rep[f"s{i}"]["dispatches_per_window"] == 5.0
    assert rep["dispatch"]["ingress"]["dispatches"] == 1
    assert rep["dispatch"]["egress"]["dispatches"] == 1
    assert dispatch_count() == 8 * 5 + 1 + 1


def test_monitored_8stage_rekey_revocation_bit_identical():
    """Monitoring must not change a single bit of the acceptance stream
    (8 stages, rekey_every_n=3, mid-stream revocation of s3/w1), and the
    monitor snapshot must carry every stage + the ingress/egress hops."""
    from test_obs import _run_8stage
    src = _src()
    _, got_off, _ = _run_8stage(src)                     # monitor off
    mon = PipelineMonitor()
    p, got, _ = _run_8stage(src, monitor=mon)
    assert len(got) == len(got_off) == len(src)
    for a, b in zip(got, got_off):
        assert np.array_equal(a, b)
    snap = mon.snapshot()
    assert set(snap["stages"]) == {f"s{i}" for i in range(8)} \
        | {"ingress", "egress"}
    s3 = snap["stages"]["s3"]
    assert s3["windows_total"] >= 1 and s3["p95_s"] is not None
    assert s3["dispatches_per_window"] > 0
    # the revoked worker's share shows up in the skew accounting
    assert set(s3["worker_rows"]) <= {0, 1}
    assert snap["pipeline"]["windows_total"] == sum(
        st["windows_total"] for st in snap["stages"].values())
    assert snap["pipeline"]["rekey_per_s"] > 0
    assert snap["pipeline"]["revocation_per_s"] > 0
    # and the whole thing exports cleanly
    assert check_prometheus.validate(
        prometheus_text(REGISTRY, mon),
        require_labels=(("stage", "s3"), ("stage", "egress"))) == []


def test_injected_mac_failure_burst_trips_watchdog_once(monkeypatch):
    """Tamper a burst of rows mid-stream: the stage that opens them sees
    the failure-rate spike, the watchdog trips EXACTLY once, and the
    ``slo_breach`` event lands in the pipeline's own audit log among the
    mac_failure events."""
    from repro.attest.directory import KeyDirectory
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline, Stage

    TAMPER = {1, 3, 6}
    pending = set(TAMPER)
    orig_pool = Pipeline._worker_pool

    def patched_pool(self, i, st):
        pool = orig_pool(self, i, st)
        if st.name != "s1":
            return pool
        for ex in pool:
            orig_rsw = ex.run_static_window

            def tampered(op, const, win, _orig=orig_rsw):
                out, ok = _orig(op, const, win)
                hit = [j for j, c in enumerate(out.counters)
                       if c in pending]
                if hit:
                    pending.difference_update(out.counters[j] for j in hit)
                    words = out.words
                    for j in hit:
                        words = words.at[j, 0].add(np.uint32(1))
                    out = dataclasses.replace(out, words=words)
                return out, ok

            ex.run_static_window = tampered
        return pool

    monkeypatch.setattr(Pipeline, "_worker_pool", patched_pool)

    mon = PipelineMonitor()
    fired = []
    wd = Watchdog(mon, [SLORule("mac-ceiling",
                                max_mac_failure_rate=0.1)],
                  on_breach=[fired.append])
    stages = [Stage(f"s{i}", op="scale_f32", const=1.01) for i in range(4)]
    d = KeyDirectory(seed=0)
    p = Pipeline(stages, SecureStreamConfig(mode="encrypted"),
                 directory=d, window_chunks=8, monitor=mon)
    got = []
    p.run(iter(_src(9)), on_result=lambda r: got.append(np.asarray(r)))
    assert not pending and len(got) == 9 - len(TAMPER)
    assert [b.rule for b in fired] == ["mac-ceiling"]    # EXACTLY once
    assert fired[0].kind == "slo_breach"
    assert fired[0].stage == "s2"       # the stage that opens s1's output
    # the matching audit event, in the pipeline's own ordered stream
    breaches = d.audit.events("slo_breach")
    assert len(breaches) == 1
    assert breaches[0].detail["rule"] == "mac-ceiling"
    assert breaches[0].detail["metric"] == "mac_failure_rate"
    assert d.audit.counts()["mac_failure"] == len(TAMPER)
    assert wd.breached() == ["mac-ceiling"]
    assert mon.stage_stats("s2")["mac_failures"] == len(TAMPER)


def test_dsl_monitor_verb_and_run_override():
    from repro.dsl import stream
    src = _src(8)
    sb = (stream(src).map("scale_f32", const=1.25, name="m")
          .secure("encrypted").window(4).monitor())
    assert sb.health_monitor is not None and sb.health_monitor.enabled
    got = []
    sb.run(on_result=lambda r: got.append(np.asarray(r)))
    assert len(got) == len(src)
    snap = sb.health_monitor.snapshot()
    assert snap["stages"]["m"]["windows_total"] == 2
    rep = sb.report()["m"]
    assert rep["windows"] == 2 and rep["dispatches_per_window"] == 2.0
    # unmonitored builders stay unmonitored (zero-cost default)
    assert stream(src).map("identity").health_monitor is None
    # per-run override on a bare pipeline
    p = sb.pipeline
    mon2 = PipelineMonitor()
    p.run(iter(src), monitor=mon2)
    assert mon2.snapshot()["stages"]["m"]["windows_total"] == 2
    assert p.monitor is sb.health_monitor       # restored after the run


def test_chunked_oracle_engine_feeds_the_monitor():
    from repro.attest.directory import KeyDirectory
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline, Stage
    mon = PipelineMonitor()
    p = Pipeline([Stage("s0", op="scale_f32", const=1.5)],
                 SecureStreamConfig(mode="encrypted"),
                 directory=KeyDirectory(seed=0), window_chunks=1,
                 monitor=mon)
    got = []
    p.run(iter(_src(3)), on_result=lambda r: got.append(np.asarray(r)))
    assert len(got) == 3
    st = mon.stage_stats("s0")
    assert st["windows_total"] == 3     # the oracle's window IS a chunk
    assert p.report()["s0"]["windows"] == 3


def test_dispatch_shims_next_to_host_sync_count():
    from repro.core import pipeline as P
    reset_dispatch_count()
    assert P.dispatch_count() == 0
    REGISTRY.counter("device.dispatches").inc(3)
    REGISTRY.counter("device.dispatches.aead.seal_many").inc(3)
    assert P.dispatch_count() == dispatch_count() == 3
    P.reset_dispatch_count()
    assert dispatch_count() == 0
    assert REGISTRY.counter("device.dispatches.aead.seal_many").value == 0
