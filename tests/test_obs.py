"""repro.obs: metrics registry, span tracer, security audit log, and the
telemetry wiring through the streaming engine (PR 6 acceptance).

The acceptance run mirrors test_attest's 8-stage rekey+revocation
pipeline, traced: per-window/per-stage/per-worker spans export as valid
Chrome-trace JSON, the audit log's event counts exactly match engine
behaviour (k tampered rows -> exactly k ``mac_failure`` events, rekeys
and the revocation in stream order), and output is bit-identical with
tracing on vs off.
"""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (AuditLog, Counter, Gauge, Histogram, MetricsRegistry,
                       NULL_TRACER, REGISTRY, Tracer)
from repro.obs.trace import _NOOP_SPAN


# ------------------------------------------------------------------ metrics


def test_registry_get_or_create_returns_same_object():
    r = MetricsRegistry()
    c = r.counter("x.count")
    c.inc()
    c.inc(2)
    assert r.counter("x.count") is c          # hot-path refs stay valid
    assert c.value == 3
    r.reset()
    assert r.counter("x.count") is c and c.value == 0


def test_registry_kind_collision_is_an_error():
    r = MetricsRegistry()
    r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")
    with pytest.raises(TypeError):
        r.histogram("x")


def test_gauge_and_snapshot():
    r = MetricsRegistry()
    r.gauge("depth").set(7)
    r.counter("n").inc(5)
    snap = r.snapshot()
    assert snap["depth"] == 7 and snap["n"] == 5
    r.reset(prefix="dep")
    assert r.gauge("depth").value == 0 and r.counter("n").value == 5


def test_histogram_percentiles_and_eviction():
    h = Histogram("lat", max_samples=100)
    assert h.percentile(50) is None and h.mean is None
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(0) == 1.0 and h.percentile(100) == 100.0
    assert 50.0 <= h.percentile(50) <= 51.0   # exact index, not interp
    s = h.summary()
    assert s["count"] == 100 and s["max"] == 100.0
    assert s["p95"] == pytest.approx(95.0, abs=1.0)
    # eviction drops the OLDEST sample once past max_samples
    h.observe(1000.0)
    assert h.count == 101                     # lifetime count keeps going
    assert h.percentile(0) == 2.0             # sample 1.0 was evicted
    assert h.summary()["max"] == 1000.0


# ------------------------------------------------------------------- tracer


def test_null_tracer_is_a_shared_noop():
    assert NULL_TRACER.enabled is False
    s1 = NULL_TRACER.span("anything", x=1)
    s2 = NULL_TRACER.span("else")
    assert s1 is s2 is _NOOP_SPAN             # no allocation per span
    with s1:
        pass
    assert NULL_TRACER.instant("mark") is None


def test_tracer_parent_child_and_find():
    tr = Tracer()
    with tr.span("outer", cat="pipeline", track="main", w=1):
        with tr.span("inner", cat="dispatch", track="s0/w0"):
            pass
        tr.instant("mark", track="main")
    assert len(tr) == 3
    outer, inner, mark = tr.spans
    assert inner.parent == outer.id and mark.parent == outer.id
    assert outer.parent is None
    assert outer.end is not None and outer.dur >= inner.dur
    assert [s.name for s in tr.children(outer)] == ["inner", "mark"]
    assert tr.find("inner")[0] is inner
    assert tr.find(cat="dispatch") == [inner]


def test_tracer_chrome_export_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("a", track="main", rows=4):
        with tr.span("b", track="s0/w1"):
            pass
    tr.instant("flip", cat="security", track="ingress", epoch=1)
    path = tmp_path / "trace.json"
    doc = tr.export_chrome(str(path))
    loaded = json.loads(path.read_text())     # valid JSON on disk
    assert loaded == doc
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta}
    assert {"repro.pipeline", "main", "s0/w1", "ingress"} <= names
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"a", "b"} and all("dur" in e for e in xs.values())
    assert xs["a"]["args"]["rows"] == 4
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["name"] == "flip"
    # distinct tracks land on distinct tids
    assert xs["a"]["tid"] != xs["b"]["tid"]
    assert "flip" in tr.timeline() and "a" in tr.timeline()


# ---------------------------------------------------------------- audit log


def test_audit_log_order_counts_and_unknown_kind():
    log = AuditLog()
    log.record("rekey", epoch=1)
    log.record("mac_failure", stage="s0", row=3, epoch=0)
    log.record("rekey", epoch=2)
    log.record("revocation", worker="s0/w1")
    assert len(log) == 4
    assert log.kind_sequence() == ["rekey", "mac_failure", "rekey",
                                   "revocation"]
    assert log.kind_sequence("rekey", "revocation") == \
        ["rekey", "rekey", "revocation"]
    assert [e.seq for e in log] == [0, 1, 2, 3]
    assert log.counts()["rekey"] == 2 and log.counts()["eviction"] == 0
    assert log.events("mac_failure")[0].detail["row"] == 3
    assert log.summary() == {"events": 4, "dropped": 0, "rekey": 2,
                             "mac_failure": 1, "revocation": 1}
    assert log.dump()[0] == {"seq": 0, "kind": "rekey", "epoch": 1}
    assert "rekey" in str(log.events("rekey")[0])
    with pytest.raises(ValueError):
        log.record("typo_kind")
    with pytest.raises(ValueError):
        log.events("typo_kind")


def test_audit_log_is_bounded():
    log = AuditLog(max_events=4)
    for i in range(10):
        log.record("rekey", epoch=i)
    assert len(log) == 4 and log.dropped == 6
    assert [e.detail["epoch"] for e in log] == [6, 7, 8, 9]
    assert log.summary()["dropped"] == 6


# ----------------------------------------------- directory lifecycle events


def _two_party_directory(seed=0):
    from repro.attest.directory import KeyDirectory
    from repro.attest.measure import IO_ENDPOINT
    d = KeyDirectory(seed=seed)
    d.enroll("a", IO_ENDPOINT, allow=True)
    d.enroll("b", IO_ENDPOINT, allow=True)
    d.establish("e", "a", "b")
    return d


def test_directory_audits_rekey_and_revocation_in_order():
    d = _two_party_directory()
    d.advance_epoch()
    d.advance_epoch()
    d.revoke("b")
    assert d.audit.kind_sequence("rekey", "revocation") == \
        ["rekey", "rekey", "revocation"]
    assert [e.detail["epoch"] for e in d.audit.events("rekey")] == [1, 2]
    rev = d.audit.events("revocation")[0]
    assert rev.detail["worker"] == "b" and rev.detail["edges"] == ["e"]


def test_directory_audits_quote_rejection():
    d = _two_party_directory()
    d.enroll("evil", b"\x13" * 32)            # measurement NOT allowlisted
    assert not d.is_admitted("evil")
    rejected = d.audit.events("quote_rejected")
    assert rejected and rejected[-1].detail["worker"] == "evil"
    d.revoke("b")
    assert not d.is_admitted("b")
    assert d.audit.events("quote_rejected")[-1].detail["reason"] == "revoked"


def test_directory_audits_nonce_exhaustion():
    from repro.crypto.keys import NONCE_COUNTER_MAX, NonceExhaustedError
    d = _two_party_directory(seed=1)
    d.session("e").chunks = NONCE_COUNTER_MAX
    assert d.next_counters("e", 1) == NONCE_COUNTER_MAX   # last valid one
    with pytest.raises(NonceExhaustedError):
        d.next_counters("e", 1)
    ev = d.audit.events("nonce_exhausted")
    assert len(ev) == 1 and ev[0].detail["edge"] == "e"


# -------------------------------------------------------- legacy count shims


def test_host_sync_shim_reads_the_registered_counter():
    from repro.core import pipeline as P
    P.reset_host_sync_count()
    assert P.host_sync_count() == 0
    REGISTRY.counter("pipeline.host_syncs").inc(3)
    assert P.host_sync_count() == 3
    P.reset_host_sync_count()
    assert REGISTRY.counter("pipeline.host_syncs").value == 0


def test_exchange_call_shim_reads_the_registered_counter():
    from repro.dist import collectives
    c0 = collectives.exchange_call_count()
    REGISTRY.counter("dist.exchange_calls").inc()
    assert collectives.exchange_call_count() == c0 + 1


def test_fastpath_stats_shim_reads_the_registered_counters():
    from repro.crypto import aead
    aead.reset_fastpath_stats()
    s = aead.fastpath_stats()
    assert s["compiles"] == 0 and s["hits"] == 0
    assert REGISTRY.get("aead.fastpath.compiles") is not None
    REGISTRY.counter("aead.fastpath.hits").inc(2)
    assert aead.fastpath_stats()["hits"] == 2
    aead.reset_fastpath_stats()


# ------------------------------------------------------- StageMetrics fixes


def test_stage_metrics_distinguish_unmeasured_from_zero():
    from repro.core.pipeline import StageMetrics
    m = StageMetrics()
    assert m.throughput_mbps is None          # nothing measured yet
    assert m.mac_failure_rate is None         # no rows seen yet
    m.seconds = 0.5                           # time passed, zero payload
    assert m.throughput_mbps == 0.0
    m.bytes = 1_000_000
    assert m.throughput_mbps == 2.0
    m.chunks, m.mac_failures = 6, 2
    assert m.mac_failure_rate == pytest.approx(0.25)
    m2 = StageMetrics(chunks=0, mac_failures=4, seconds=1.0)
    assert m2.mac_failure_rate == 1.0 and m2.throughput_mbps == 0.0


def test_report_is_none_safe_before_any_run():
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline, Stage
    p = Pipeline([Stage("s", op="identity")],
                 SecureStreamConfig(mode="plain"))
    rep = p.report()["s"]
    assert rep["throughput_mbps"] is None
    assert rep["mac_failure_rate"] is None
    assert rep["chunks"] == 0 and rep["mac_failures"] == 0


# ------------------------------------------------- engine integration (e2e)


def _stage8():
    from repro.core.pipeline import Stage
    return [Stage(f"s{i}", op="scale_f32", const=1.0 + 0.125 * i,
                  workers=2 if i % 3 == 0 else 1) for i in range(8)]


def _src(n=9):
    return [jnp.asarray(np.random.default_rng(i).standard_normal(
        (64,)).astype(np.float32)) for i in range(n)]


def _run_8stage(src, tracer=None, monitor=None):
    """One 8-stage encrypted run with rekey_every_n=3 and a mid-stream
    revocation of s3/w1; returns (pipeline, outputs, epoch_at_revoke)."""
    from repro.attest.directory import KeyDirectory
    from repro.configs.base import SecureStreamConfig
    from repro.core.pipeline import Pipeline
    p = Pipeline(_stage8(), SecureStreamConfig(mode="encrypted"),
                 directory=KeyDirectory(seed=0, epoch_history=64),
                 window_chunks=8)
    state = {}

    def source():
        for i, c in enumerate(src):
            if i == 4:
                state["epoch_at_revoke"] = p.directory.epoch
                p.directory.revoke(Pipeline.worker_id("s3", 1))
            yield c

    got = []
    p.run(source(), on_result=lambda r: got.append(np.asarray(r)),
          rekey_every_n=3, tracer=tracer, monitor=monitor)
    return p, got, state["epoch_at_revoke"]


def test_traced_8stage_rekey_revocation_acceptance(tmp_path):
    """THE acceptance run: spans + audit + bit-identity, one traced run
    vs one untraced run of the same rekey+revocation stream."""
    src = _src()
    p_off, got_off, _ = _run_8stage(src)                 # tracing off
    tr = Tracer()
    p, got, epoch_at_revoke = _run_8stage(src, tracer=tr)

    # tracing must not change a single bit of the stream
    assert len(got) == len(got_off) == len(src)
    for a, b in zip(got, got_off):
        assert np.array_equal(a, b)

    # -- audit: counts exactly match engine behaviour, in stream order --
    audit = p.directory.audit
    assert audit.counts()["rekey"] == p.directory.epoch >= 2
    assert audit.counts()["revocation"] == 1
    assert audit.counts()["mac_failure"] == 0            # nothing tampered
    assert audit.counts()["eviction"] == 1
    ev = audit.events("eviction")[0]
    assert ev.detail["worker"] == "s3/w1"
    # the revocation sits between exactly the rekeys that preceded and
    # followed it: every rekey to an epoch <= epoch_at_revoke comes
    # before it, every later rekey after
    rev_seq = audit.events("revocation")[0].seq
    for e in audit.events("rekey"):
        if e.detail["epoch"] <= epoch_at_revoke:
            assert e.seq < rev_seq
        else:
            assert e.seq > rev_seq
    # revocation precedes the engine's first skipped dispatch (eviction)
    assert rev_seq < ev.seq

    # -- spans: per-window, per-stage, per-worker ------------------------
    assert tr.find("pipeline.run")
    assert tr.find("ingress.seal") and tr.find("stage.dispatch")
    assert tr.find("sync.verdicts") and tr.find("egress.open")
    assert len(tr.find("rekey")) == p.directory.epoch    # one per flip
    tracks = {s.track for s in tr.spans}
    assert "ingress" in tracks and "sink" in tracks
    assert "s0/w0" in tracks and "s0/w1" in tracks       # per-worker lanes
    # every stage got at least one dispatch span on its own lane
    stage_lanes = {s.track for s in tr.find("stage.dispatch")}
    assert stage_lanes == {f"s{i}" for i in range(8)}
    # phase spans nest under their stage's dispatch span
    open_spans = tr.find("enclave.open")
    assert open_spans
    parents = {tr.spans[s.parent].name for s in open_spans}
    assert parents == {"stage.dispatch"}

    # -- Chrome export: valid, loadable JSON with named lanes ------------
    path = tmp_path / "trace.json"
    doc = tr.export_chrome(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"]
    phs = {e["ph"] for e in loaded["traceEvents"]}
    assert {"X", "M", "i"} <= phs
    lane_names = {e["args"]["name"] for e in loaded["traceEvents"]
                  if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"ingress", "sink", "s3/w0"} <= lane_names
    assert doc == loaded

    # untraced pipeline defaults to the shared zero-cost NULL tracer
    assert p_off.tracer is NULL_TRACER


def test_k_tampered_rows_yield_exactly_k_audit_events(monkeypatch):
    """Tamper k sealed rows on stage s1's output edge: the next stage's
    batched open drops exactly those rows, the audit log records exactly
    k ``mac_failure`` events carrying each row's counter + epoch."""
    from repro.attest.directory import KeyDirectory
    from repro.configs.base import SecureStreamConfig
    from repro.core.enclave import EnclaveExecutor
    from repro.core.pipeline import Pipeline, Stage

    TAMPER = {1, 3, 6}
    k = len(TAMPER)
    pending = set(TAMPER)

    orig_pool = Pipeline._worker_pool

    def patched_pool(self, i, st):
        pool = orig_pool(self, i, st)
        if st.name != "s1":
            return pool
        for ex in pool:
            orig_rsw = ex.run_static_window

            def tampered(op, const, win, _orig=orig_rsw):
                out, ok = _orig(op, const, win)
                hit = [j for j, c in enumerate(out.counters)
                       if c in pending]
                if hit:
                    pending.difference_update(out.counters[j] for j in hit)
                    words = out.words
                    for j in hit:             # flip one word, keep the tag
                        words = words.at[j, 0].add(np.uint32(1))
                    out = dataclasses.replace(out, words=words)
                return out, ok

            ex.run_static_window = tampered
        return pool

    monkeypatch.setattr(Pipeline, "_worker_pool", patched_pool)

    stages = [Stage(f"s{i}", op="scale_f32", const=1.01) for i in range(4)]
    d = KeyDirectory(seed=0)
    p = Pipeline(stages, SecureStreamConfig(mode="encrypted"),
                 directory=d, window_chunks=8)
    src = _src(9)
    got = []
    p.run(iter(src), on_result=lambda r: got.append(np.asarray(r)))

    assert not pending                         # every target row was hit
    # tampered rows are dropped at s2 (the stage that opens s1's output)
    assert len(got) == len(src) - k
    failures = d.audit.events("mac_failure")
    assert len(failures) == k                  # EXACTLY k events, no more
    assert sorted(e.detail["row"] for e in failures) == sorted(TAMPER)
    assert all(e.detail["stage"] == "s2" for e in failures)
    assert all("epoch" in e.detail for e in failures)
    assert p.metrics["s2"].mac_failures == k
    assert p.metrics["s2"].mac_failure_rate == pytest.approx(
        k / len(src))
    rep = p.report()
    assert rep["audit"]["mac_failure"] == k
    # downstream stages only ever saw the survivors
    assert p.metrics["s3"].chunks == len(src) - k


def test_dsl_trace_and_per_stage_histograms():
    """``stream(...).trace()`` attaches a tracer through the compiler,
    and the engine feeds the per-stage latency histograms + queue-depth
    gauges registered in the process-wide REGISTRY."""
    from repro.dsl import stream

    REGISTRY.reset(prefix="pipeline.stage.obs_hist")
    src = _src(8)
    sb = (stream(src)
          .map("scale_f32", const=1.25, name="obs_hist", workers=2)
          .secure("encrypted").window(4).trace())
    assert sb.tracer is not None and sb.tracer.enabled
    got = []
    sb.run(on_result=lambda r: got.append(np.asarray(r)))
    assert len(got) == len(src)
    assert sb.tracer is sb.pipeline.tracer
    assert sb.tracer.find("stage.dispatch")
    h = REGISTRY.get("pipeline.stage.obs_hist.window_seconds")
    assert h is not None and h.count >= 1
    assert h.summary()["p50"] is not None
    assert REGISTRY.get("pipeline.stage.obs_hist.queue_rows") is not None
    # untraced builders stay untraced (zero-cost default)
    assert stream(src).map("identity").tracer is None


def test_chunked_oracle_engine_is_traced_and_audited(monkeypatch):
    """The window_chunks=1 per-chunk oracle engine feeds the same
    telemetry: spans, host-sync counter, and mac_failure audit events."""
    from repro.attest.directory import KeyDirectory
    from repro.configs.base import SecureStreamConfig
    from repro.core import pipeline as P
    from repro.core.pipeline import Pipeline, Stage

    d = KeyDirectory(seed=0)
    p = Pipeline([Stage("s0", op="scale_f32", const=1.5)],
                 SecureStreamConfig(mode="encrypted"), directory=d,
                 window_chunks=1)
    tr = Tracer()
    src = _src(3)
    got = []
    P.reset_host_sync_count()
    p.run(iter(src), on_result=lambda r: got.append(np.asarray(r)),
          tracer=tr)
    assert len(got) == 3
    assert P.host_sync_count() == 6            # per-chunk: stage + egress
    assert len(tr.find("stage.chunk")) == 3
    assert tr.find("pipeline.run")
    assert d.audit.counts()["mac_failure"] == 0
