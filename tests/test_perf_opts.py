"""Tests for the §Perf optimizations: hierarchical causal attention,
per-arch sharding rules, FSDP expert-weight specs, O(log n) MAC ladder."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_run_config
from repro.dist.meshctx import MeshContext
from repro.models.flash import flash_attention
from repro.models.hier_attn import hier_causal_attention


@pytest.mark.parametrize("S,base", [(256, 64), (512, 128), (512, 64)])
def test_hier_attention_matches_flash(S, base):
    B, H, D = 2, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ref = flash_attention(q, k, v, True, 64, 64)
    out = hier_causal_attention(q, k, v, base=base, q_chunk=64, kv_chunk=64)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_hier_attention_halves_hlo_flops():
    from repro.launch.hloanalysis import analyze
    B, S, H, D = 1, 512, 1, 16
    sds = jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)
    c1 = jax.jit(lambda q, k, v: flash_attention(q, k, v, True, 64, 64)) \
        .lower(sds, sds, sds).compile()
    c2 = jax.jit(lambda q, k, v: hier_causal_attention(
        q, k, v, base=64, q_chunk=64, kv_chunk=64)) \
        .lower(sds, sds, sds).compile()
    a1 = analyze(c1.as_text())
    a2 = analyze(c2.as_text())
    # theoretical: 0.5 + O(base/S); allow generous slack
    assert a2.flops < 0.65 * a1.flops, (a1.flops, a2.flops)


def test_per_arch_sharding_rules_applied():
    # llama: pure-DP rules
    run = get_run_config("llama3.2-1b", "train_4k")
    rules = run.sharding.lookup()
    assert rules["heads"] == () and rules["mlp"] == ()
    assert rules["batch"] == ("pod", "data", "model")
    # kimi: FSDP experts + SP residual
    run = get_run_config("kimi-k2-1t-a32b", "train_4k")
    rules = run.sharding.lookup()
    assert rules["moe_ff"] == ("data",)
    assert rules["seq_res"] == ("model",)
    # granite: SP residual, gelu MLP
    run = get_run_config("granite-34b", "train_4k")
    assert run.sharding.lookup()["seq_res"] == ("model",)
    assert run.model.mlp_type == "gelu"


def test_fsdp_expert_weight_specs():
    """kimi expert weights must be sharded over BOTH axes at rest."""
    from repro.models.moe import moe_template
    from repro.models.layers import shardings_from_template
    run = get_run_config("kimi-k2-1t-a32b", "train_4k")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshContext(mesh=mesh, rules=run.sharding.lookup())
    sh = shardings_from_template(moe_template(run.model), ctx)
    assert sh["wg"].spec == P("model", None, "data")
    assert sh["wd"].spec == P("model", "data", None)


def test_r_powers_log_doubling_correct():
    from repro.crypto.cwmac import mulmod, r_powers
    p = (1 << 31) - 1
    r = 123456789
    ps = np.asarray(r_powers(jnp.uint32(r), 37))
    want = [pow(r, e, p) for e in range(37, 0, -1)]
    assert list(ps) == want


def test_mlp_gelu_vs_swiglu_param_difference():
    import dataclasses
    from repro.configs import get_model_config
    m = get_model_config("granite-34b")
    m_swiglu = dataclasses.replace(m, mlp_type="swiglu")
    extra = m_swiglu.param_count() - m.param_count()
    assert extra == m.num_layers * m.d_model * m.d_ff  # exactly one matrix
