"""Per-architecture smoke tests: reduced configs of the same family run a
forward + train step on CPU; output shapes verified and loss/grads finite.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_model_config, reduce_for_smoke
from repro.models import api

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(ks[2], (B, 8, cfg.frontend_dim),
                                             jnp.bfloat16)
    elif cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.frontend_dim),
                                            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_and_loss(arch, ctx, rng):
    cfg = reduce_for_smoke(get_model_config(arch))
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    hidden, aux = api.forward(cfg, params, batch, ctx)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(hidden.astype(jnp.float32)).any())
    loss, metrics = api.loss_fn(cfg, params, batch, ctx)
    assert jnp.isfinite(loss), arch
    # loss should be near ln(vocab) for random init
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_grads_finite(arch, ctx, rng):
    cfg = reduce_for_smoke(get_model_config(arch))
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    g = jax.grad(lambda p: api.loss_fn(cfg, p, batch, ctx)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_shapes(arch, ctx, rng):
    cfg = reduce_for_smoke(get_model_config(arch))
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits, cache = api.prefill(cfg, params, batch, ctx, max_seq=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    nt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = api.decode_step(cfg, params, nt, jnp.int32(S), cache,
                                      ctx)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_forward(arch, ctx, rng):
    """Teacher-forced decode must reproduce the full forward's last logits:
    prefill 16 tokens, decode tokens 16..31, compare final logits with the
    full 32-token forward (capacity boosted for MoE so no tokens drop)."""
    import dataclasses
    cfg = reduce_for_smoke(get_model_config(arch))
    if cfg.frontend != "none":
        pytest.skip("frontend stubs inject prompt-side embeddings only")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = api.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]

    hidden, _ = api.forward(cfg, params, batch, ctx, remat="none")
    W = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ref = jnp.einsum("BE,EV->BV", hidden[:, -1], W,
                     preferred_element_type=jnp.float32)

    half = S // 2
    pre = dict(batch)
    pre["tokens"] = tokens[:, :half]
    _, cache = api.prefill(cfg, params, pre, ctx, max_seq=S)
    for t in range(half, S):
        got, cache = api.decode_step(cfg, params, tokens[:, t:t + 1],
                                     jnp.int32(t), cache, ctx)
    err = jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-6)
    if cfg.moe is not None:
        # MoE decode: bf16-level differences between the chunked (prefill)
        # and single-pass (decode) attention can flip top-k router choices
        # near ties — an inherent property of capacity-routed MoE serving.
        # Assert the decision-level invariant instead of logit closeness.
        agree = jnp.mean((jnp.argmax(got, -1) == jnp.argmax(ref, -1))
                         .astype(jnp.float32))
        assert float(agree) == 1.0, (arch, float(agree), float(err))
    else:
        assert float(err) < 0.08, (arch, float(err))


def test_all_cells_defined():
    from repro.configs import all_cells, cell_supported
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if not cell_supported(*c)[0]]
    assert len(skips) == 8  # long_500k on the 8 full-attention archs


def test_param_counts_sane():
    # spot checks against the arch names
    assert 0.9e12 < get_model_config("kimi-k2-1t-a32b").param_count() < 1.3e12
    a32 = get_model_config("kimi-k2-1t-a32b").active_param_count()
    assert 25e9 < a32 < 40e9
    assert 27e9 < get_model_config("qwen2.5-32b").param_count() < 37e9
    assert 1.0e9 < get_model_config("llama3.2-1b").param_count() < 1.7e9
    assert 12e9 < get_model_config("qwen2.5-14b").param_count() < 17e9
    assert 30e9 < get_model_config("granite-34b").param_count() < 40e9
    assert 0.10e9 < get_model_config("xlstm-125m").param_count() < 0.2e9
    assert 1.0e9 < get_model_config("zamba2-1.2b").param_count() < 1.65e9
