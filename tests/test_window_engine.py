"""Window-vectorized streaming engine: batched open->op->seal windows,
deferred MAC verdicts (one host sync per window), prefetching ingress with
reserved counter blocks, and the wc=1 per-chunk oracle parity."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attest.directory import KeyDirectory, ephemeral_edge_key
from repro.configs.base import SecureStreamConfig
from repro.core import pipeline as P
from repro.core.enclave import (EnclaveExecutor, open_tensor, seal_tensor,
                                seal_tensor_many, window_from_chunks,
                                window_to_chunks)
from repro.core.pipeline import Pipeline, Stage
from repro.crypto import aead


def _src(n, words=64, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(words).astype(np.float32))
            for _ in range(n)]


def _one_stage(mode="encrypted", wc=8, workers=1):
    return Pipeline([Stage("s", op="scale_f32", const=2.0,
                           workers=workers)],
                    SecureStreamConfig(mode=mode), window_chunks=wc)


# ----------------------------------------------------- deferred verdicts


def test_deferred_verdicts_tamper_k_rows():
    """Tamper k rows of a window: exactly k mac_failures, the other rows
    survive, and downstream stage order is preserved."""
    p = _one_stage(wc=8)
    h0, h1 = p.keys[0], p.keys[1]
    xs = _src(8)
    chunks = [seal_tensor(h0, i, x) for i, x in enumerate(xs)]
    bad = {2, 5, 6}
    for i in bad:
        chunks[i].blocks = chunks[i].blocks.at[0, 0].add(np.uint32(1))

    st = p.stages[0]
    pool = p._worker_pool(0, st)
    P.reset_host_sync_count()
    wins = list(p._stage_stream(iter([window_from_chunks(chunks)]), st,
                                pool, 8))
    assert P.host_sync_count() == 1                  # ONE sync per window

    m = p.metrics["s"]
    assert m.mac_failures == len(bad)
    assert m.chunks == len(xs) - len(bad)
    assert pool[0].errors == len(bad)
    # survivors in original stream order, correct values
    outs = [c for w in wins for c in window_to_chunks(w)]
    assert [c.counter for c in outs] == [0, 1, 3, 4, 7]
    for c in outs:
        y, ok = open_tensor(h1, c)
        assert bool(ok)
        assert np.array_equal(np.asarray(y), np.asarray(xs[c.counter]) * 2.0)


@pytest.mark.parametrize("mode", ["encrypted", "enclave"])
def test_executor_verdict_vector_stays_on_device(mode):
    """run_static_many returns per-row verdicts WITHOUT a host sync: the
    vector is a device array, not a Python bool."""
    k0 = ephemeral_edge_key("in", seed=3)
    k1 = ephemeral_edge_key("out", seed=4)
    chunks = [seal_tensor(k0, i, x) for i, x in enumerate(_src(4))]
    chunks[1].blocks = chunks[1].blocks.at[0, 3].add(np.uint32(9))
    ex = EnclaveExecutor(mode, k0, k1)
    outs, ok = ex.run_static_many("identity", 0.0, chunks)
    assert isinstance(ok, jax.Array) and ok.shape == (4,)
    assert list(np.asarray(ok)) == [True, False, True, True]
    assert len(outs) == 4                            # candidates for ALL rows


# -------------------------------------------------- one host sync/window


def test_one_host_sync_per_window_regression_gate():
    """The engine must sync once per WINDOW, not once per chunk: 8 chunks
    at wc=4 -> 2 stage windows + 2 egress windows; the wc=1 oracle pays
    8 + 8.  A regression back to per-chunk syncing fails here."""
    got = {}
    for wc in (4, 1):
        p = _one_stage(wc=wc)
        P.reset_host_sync_count()
        res = []
        p.run(iter(_src(8)), on_result=lambda r: res.append(r))
        got[wc] = P.host_sync_count()
        assert len(res) == 8
    assert got[4] == 2 + 2
    assert got[1] == 8 + 8


# ------------------------------------------------ batched == per-chunk


@pytest.mark.parametrize("mode", ["plain", "encrypted", "enclave"])
def test_windowed_engine_bit_identical_to_per_chunk(mode):
    """wc=8 windows vs the wc=1 oracle: bit-identical results, including
    a ragged tail chunk (its own uniform run)."""
    xs = _src(9) + [jnp.asarray(np.arange(24, dtype=np.float32))]
    outs = {}
    for wc in (1, 8):
        p = Pipeline([Stage("a", op="scale_f32", const=1.5),
                      Stage("b", op="relu_f32", workers=2)],
                     SecureStreamConfig(mode=mode), window_chunks=wc)
        got = []
        p.run(iter(xs), on_result=lambda r: got.append(np.asarray(r)))
        outs[wc] = got
    assert len(outs[1]) == len(outs[8]) == len(xs)
    for a, b in zip(outs[1], outs[8]):
        assert np.array_equal(a, b)


def test_steady_state_streaming_compiles_nothing():
    """Round 2 of identical windows must hit the shape-keyed compile
    cache only — zero new programs."""
    p = _one_stage(wc=8)
    p.run(iter(_src(8)))
    compiles = aead.fastpath_stats()["compiles"]
    hits = aead.fastpath_stats()["hits"]
    p.run(iter(_src(8, seed=1)))
    stats = aead.fastpath_stats()
    assert stats["compiles"] == compiles             # nothing new compiled
    assert stats["hits"] > hits


def test_window_metrics_time_execution_not_enqueue():
    """StageMetrics.seconds spans a block_until_ready on the window's
    outputs, so per-stage seconds are real and bounded by wall time."""
    p = _one_stage(wc=8)
    import time
    t0 = time.perf_counter()
    p.run(iter(_src(8)))
    wall = time.perf_counter() - t0
    rep = p.report()["s"]
    assert 0.0 < rep["seconds"] <= wall
    assert rep["throughput_mbps"] > 0.0


# -------------------------------------------- ingress counter reservation


def test_ingress_reserves_counter_blocks_per_window():
    """Every sealed ingress window reserves a contiguous directory block:
    a second run (and any other edge consumer) continues AFTER it."""
    p = _one_stage(wc=4)
    p.run(iter(_src(8)))
    sess = p.directory.session("edge0")
    assert sess.chunks == 8                          # managed, not per-run
    base, epoch = p.keys[0].reserve_window(5)
    assert base == 8 and epoch == p.directory.epoch
    assert p.directory.session("edge0").chunks == 13


def test_mixed_epoch_window_opens_per_row():
    """A single batched window straddling an advance_epoch flip must open
    every row under its ingress epoch (per-row keys, no crossed
    keystreams) — checked against scalar opens."""
    d = KeyDirectory(seed=5, epoch_history=8)
    from repro.attest.measure import IO_ENDPOINT
    d.enroll("a", IO_ENDPOINT, allow=True)
    d.enroll("b", IO_ENDPOINT, allow=True)
    d.establish("e", "a", "b")
    h = d.handle("e")
    xs = _src(6, seed=2)
    chunks = seal_tensor_many(h, range(0, 3), xs[:3], epoch=d.epoch)
    d.advance_epoch()
    chunks += seal_tensor_many(h, range(0, 3), xs[3:], epoch=d.epoch)
    assert {c.epoch for c in chunks} == {0, 1}
    from repro.core.enclave import open_words_many
    pt, ok = open_words_many(h, chunks)
    assert bool(np.asarray(ok).all())
    for i, c in enumerate(chunks):
        y, ok1 = open_tensor(h, c)
        assert bool(ok1)
        assert np.array_equal(np.asarray(pt[i]),
                              np.asarray(aead.tensor_to_words(y)[0]))


# ------------------------------------------------- secure channel windows


def test_secure_channel_window_roundtrip_and_drain():
    from repro.attest.measure import IO_ENDPOINT
    from repro.core.secure_channel import SecureChannel
    d = KeyDirectory(seed=6)
    d.enroll("a", IO_ENDPOINT, allow=True)
    d.enroll("b", IO_ENDPOINT, allow=True)
    d.establish("e", "a", "b")
    ch = SecureChannel(d.handle("e"))
    xs = jnp.asarray(np.random.default_rng(0)
                     .standard_normal((5, 7, 3)).astype(np.float32))
    hdr, ct, tags, meta = ch.protect_window(xs)
    assert hdr == (0, 0)
    assert d.session("e").chunks == 5                # block reserved
    d.advance_epoch()                                # window drains post-flip
    y, ok = ch.unprotect_window(hdr, ct, tags, meta)
    assert bool(np.asarray(ok).all())
    assert np.array_equal(np.asarray(y), np.asarray(xs))
    # tampered row -> exactly that verdict flips
    bad = ct.at[3, 0].add(np.uint32(1))
    _, ok2 = ch.unprotect_window(hdr, bad, tags, meta)
    assert list(np.asarray(ok2)) == [True, True, True, False, True]
    # post-flip window seals under the new epoch's reset counter
    hdr3, *_ = ch.protect_window(xs)
    assert hdr3 == (0, 1)


# ------------------------------------------------------ rows kernel oracle


def test_enclave_map_rows_matches_ref_and_scalar_kernel():
    from repro.kernels.enclave_map import ops
    from repro.kernels.enclave_map.enclave_map import enclave_apply
    from repro.kernels.enclave_map.ref import enclave_apply_rows_ref
    rng = np.random.default_rng(1)
    R = 24
    kin = jnp.asarray(rng.integers(0, 2**32, (R, 8), dtype=np.uint32))
    kout = jnp.asarray(rng.integers(0, 2**32, (R, 8), dtype=np.uint32))
    nonces = jnp.asarray(rng.integers(0, 2**32, (R, 3), dtype=np.uint32))
    ctrs = jnp.asarray(rng.integers(1, 99, (R,), dtype=np.uint32))
    rows = jnp.asarray(rng.integers(0, 2**32, (R, 16), dtype=np.uint32))
    for op in ("identity", "scale_f32", "threshold_mask",
               "delay_filter_u32"):
        got = ops.enclave_map_rows(kin, kout, nonces, ctrs, rows,
                                   op=op, const=1.5)
        want = enclave_apply_rows_ref(kin, kout, nonces, ctrs, rows,
                                      op=op, const=1.5)
        assert np.array_equal(np.asarray(got), np.asarray(want)), op
    # one-chunk degenerate case == the scalar blocks kernel
    n1 = nonces[0]
    run_ctrs = jnp.arange(1, R + 1, dtype=jnp.uint32)
    got = ops.enclave_map_rows(kin[0], kout[0],
                               jnp.broadcast_to(n1, (R, 3)), run_ctrs,
                               rows, op="scale_f32", const=2.0)
    padded = jnp.pad(rows, ((0, (-R) % 512), (0, 0)))
    want = enclave_apply(kin[0], kout[0], n1, 1, padded, op="scale_f32",
                         const=2.0, block_rows=512, interpret=True)[:R]
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- rekey window clamp


def test_rekey_clamps_window_and_still_rejects_unsafe():
    """A rekey cadence the per-chunk oracle can't drain still fails up
    front; one the oracle CAN drain silently clamps the window factor
    instead of pruning in-flight keys."""
    p = Pipeline([Stage("s", op="scale_f32", const=2.0, workers=9)],
                 SecureStreamConfig(mode="encrypted"), window_chunks=8)
    with pytest.raises(ValueError, match="epoch_history"):
        p.run(iter(_src(12, words=8)), rekey_every_n=1)
    # safe cadence: runs (clamped), rotates, and matches the no-rekey run
    p2 = Pipeline([Stage("s", op="scale_f32", const=2.0)],
                  SecureStreamConfig(mode="encrypted"), window_chunks=8)
    got = []
    p2.run(iter(_src(12, words=8)), on_result=lambda r: got.append(
        np.asarray(r)), rekey_every_n=4)
    assert p2.directory.epoch >= 2
    want = [np.asarray(x) * 2.0 for x in _src(12, words=8)]
    for a, b in zip(got, want):
        assert np.array_equal(a, b)
